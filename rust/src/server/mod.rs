//! Live serving path: real token generation through the AOT-compiled
//! TinyQwen artifacts on PJRT CPU instances.
//!
//! Topology: a leader thread runs the global scheduler (Algorithm 1) over
//! live load digests and dispatches α/β micro-request segments to
//! instance threads over channels. Each instance thread owns a PJRT
//! [`Engine`] *and* the same [`InstanceRuntime`] lifecycle state machine
//! the discrete-event simulator drives (`crate::exec`, DESIGN.md §3):
//! admission, Algorithm-2 batch planning, prefill/decode application,
//! completion, and the α→β handoff trigger are the shared code; only the
//! executor differs — measured PJRT steps on a [`WallClock`] instead of
//! cost-model latencies in virtual time, and a live transport that
//! streams real KV chunks to β instances through the paced
//! [`TransferEngine`] (§4.3) instead of the modeled timelines. Python is
//! nowhere on this path.
//!
//! Elastic membership (DESIGN.md §Elastic): the leader owns a
//! [`LiveCluster`] directory mirroring the virtual executor's
//! `exec::Cluster`. [`LiveCluster::add_instance`] spawns a new instance
//! thread (its *real* engine bring-up is the warm-up: the member is not
//! placeable until the thread publishes readiness, but its GPU-seconds
//! accrue from spawn); [`LiveCluster::drain`] stops placements and sends
//! [`InstMsg::Drain`] — the thread finishes every resident segment
//! (gated βs included: live drains do not re-place in-flight KV, unlike
//! the virtual executor's pre-transfer re-placement) and then retires,
//! stamping its removal time so its GPU-second meter freezes. An optional
//! utilization-band autoscaler ([`ServeConfig::autoscale`]) drives
//! add/drain from the same digests the scheduler reads.
//!
//! [`virtual_executor`] is the same wiring with the engine stubbed out:
//! the server facade's deterministic virtual-time executor, pinned
//! bit-identical to the simulator facade by `rust/tests/parity.rs`.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::predictor::PredictorConfig;
use crate::coordinator::{
    GlobalConfig, LoadDigest, LocalConfig, LocalScheduler, ProfileTable, RemoteCredit,
};
use crate::core::{InstanceId, Request, RequestId};
use crate::costmodel::{GpuSpec, InstanceSpec, LlmSpec};
use crate::exec::clock::{Clock, WallClock};
use crate::exec::cluster::{
    fleet_saturated, Autoscaler, BandAutoscaler, BandConfig, DrainError, ScaleDirective,
    PREFILL_BACKLOG_BUDGET,
};
use crate::exec::policy::{DynaServePolicy, Policy};
use crate::exec::runtime::{EventSink, InstanceRuntime, Segment, SeqKey};
use crate::exec::submit::{plan_submission, SegmentPlan};
use crate::exec::migrate::MigrationPlanner;
use crate::exec::transport::{Handoff, HandoffDisposition, RemoteSeq, Transport};
use crate::exec::{ExecConfig, VirtualExecutor};
use crate::kv::{LinkSpec, PrefixView, TransferEngine, TransferJob, PREFIX_BLOCK};
use crate::metrics::{Collector, RecoveryStats, SloConfig, Summary};
use crate::runtime::{Engine, KvState};
use crate::util::rng::Rng;
use crate::workload::{PoissonArrivals, TraceKind, TraceSampler, WorkloadGen};

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts: String,
    /// Bootstrap fleet size (the autoscaler can grow/shrink from here).
    pub n_instances: usize,
    pub requests: usize,
    pub qps: f64,
    pub workload: TraceKind,
    pub seed: u64,
    pub slo: SloConfig,
    /// Install a utilization-band autoscaler on the leader: evaluated on
    /// the live digests before each placement; `None` = fixed fleet.
    pub autoscale: Option<BandConfig>,
    /// Bounded wait for engine load + calibration before the leader gives
    /// up (seconds). The default matches the historical hardcoded 300 s.
    pub calibration_deadline_s: f64,
    /// Bounded wait for at least one placeable instance at each arrival
    /// (seconds) — covers post-calibration digest publication and
    /// all-warming moments after a scale-up. Default: the historical 60 s.
    pub ready_deadline_s: f64,
    /// SLO-aware admission control: when the whole placeable fleet is
    /// saturated (every digest at pressure ≥ 1.0 — `exec::cluster::
    /// fleet_saturated`, the same predicate the virtual executor's gate
    /// evaluates), batch-class arrivals (per-request SLO present but not
    /// [`Request::interactive`]) are rejected instead of placed. The
    /// leader counts them via [`Collector::on_reject`] and stops waiting
    /// for their completions. Default off — legacy serve runs admit
    /// everything, DESIGN.md §Overload.
    pub admission: bool,
    /// Prefix-cache-aware routing on the live path (DESIGN.md §Prefix
    /// cache): instance threads maintain the same per-instance radix
    /// index over resident KV the virtual executor drives, publish
    /// compact [`PrefixView`]s into the shared fleet state, and retain
    /// the real KV tensors of recently retired lineage-tagged segments
    /// in a small engine-side pool. The leader scores placements with
    /// `Policy::place_cached` against the views and ships the credited
    /// skip as a *hint* on the segment spec — views may lag, so the
    /// owning thread re-probes its own index (clamped by the pool),
    /// claims locally, and prefills any un-granted remainder normally.
    /// Default off: cache-off serves are unchanged from pre-cache builds.
    pub cache: bool,
    /// Cross-instance prefix fetch on the live path (DESIGN.md §KV
    /// migration): the leader weighs *remote* [`PrefixView`] matches with
    /// transfer-cost-discounted credit (`Policy::place_migrate`), and when
    /// a remote span wins the head it ships the real KV rows from the
    /// holder's engine-side pool to the α instance through the same paced
    /// [`forward_kv`] path the α→β handoff uses — the α segment is gated
    /// and activates on the final chunk, exactly like a β. Requires
    /// [`ServeConfig::cache`] (without views there is nothing to fetch).
    /// Default off: fetch-off serves place identically to cache-only ones.
    pub migrate_fetch: bool,
    /// Decode-phase preemption is a *virtual-executor* feature
    /// (`ExecConfig::migrate_preempt`): it needs the atomic
    /// evict-snapshot-resubmit the event loop provides, which the live
    /// leader cannot replicate over fire-and-forget channels without a
    /// cancellation protocol. Accepted here for config parity and
    /// ignored by [`serve`] (with a warning) — see DESIGN.md §KV
    /// migration for the live-path status.
    pub migrate_preempt: bool,
}

impl ServeConfig {
    pub const DEFAULT_CALIBRATION_DEADLINE_S: f64 = 300.0;
    pub const DEFAULT_READY_DEADLINE_S: f64 = 60.0;
}

/// One placed segment, as sent to an instance thread. Field meanings
/// mirror [`crate::exec::submit::SegmentPlan`] — the leader derives both
/// from the same `plan_submission` output.
struct SegmentSpec {
    /// Leader-assigned id (executor-scoped key; the thread maps it to its
    /// arena key on accept).
    key: u64,
    request: RequestId,
    arrival: f64,
    /// Prompt token ids this segment must prefill (span ∩ [0, P)).
    prompt: Vec<i32>,
    /// Context length at which this segment starts.
    start: usize,
    /// Decode tokens to generate.
    decode_budget: usize,
    emits_first: bool,
    last_segment: bool,
    /// Forward KV + generation state here when done (β instance + key).
    beta_dest: Option<RemoteSeq>,
    /// Waits for KV before executing; activated by the final chunk
    /// (β segments, and fetch-gated α segments when `fetch > 0`).
    gated: bool,
    /// Interactive-class request (tight TTFT SLO) — priority batching
    /// input, derived leader-side from [`Request::interactive`].
    interactive: bool,
    /// KV-reuse lineage, copied from the request (prefix-cache identity).
    prefix_group: Option<u64>,
    shared_prefix: usize,
    /// Leader-credited cached-prefix skip (tokens), from the placement's
    /// view-based match. A hint, not a contract: `prompt` still covers
    /// the skipped region, and the owning thread re-probes its own index
    /// at accept time — it may grant less (views lag; eviction raced) and
    /// prefill the un-granted remainder normally.
    cached: usize,
    /// Nonzero marks a *fetch-gated* α: the `cached` span's KV is resident
    /// on another instance and arrives over the wire as [`InstMsg::Kv`]
    /// chunks, so the thread imports (rather than locally claims) the skip
    /// and the segment stays gated until the final chunk lands.
    fetch: usize,
}

impl SegmentSpec {
    /// Leader-side marshalling of one clamped [`crate::exec::submit::SegmentPlan`].
    fn from_plan(
        key: u64,
        req: &Request,
        arrival: f64,
        prompt: &[i32],
        sp: &SegmentPlan,
        beta_dest: Option<RemoteSeq>,
        gated: bool,
        fetch: usize,
    ) -> SegmentSpec {
        // ship the skipped region too — the thread may grant a smaller
        // skip than the leader's hint and must be able to prefill it
        let mut range = sp.prompt_range(req.prompt_len);
        range.start -= sp.cached;
        SegmentSpec {
            key,
            request: req.id,
            arrival,
            prompt: prompt[range].to_vec(),
            start: sp.start,
            decode_budget: sp.decode,
            emits_first: sp.emits_first,
            last_segment: sp.last_segment,
            beta_dest,
            gated,
            interactive: req.interactive(),
            prefix_group: req.prefix_group,
            shared_prefix: req.shared_prefix,
            cached: sp.cached,
            fetch,
        }
    }

    /// Instance-thread reconstruction of the lifecycle segment. This is
    /// the live half of the sim↔live parity contract: the round-trip
    /// `SegmentPlan → SegmentSpec → Segment` must land on exactly the
    /// segment `exec::submit::make_segment` builds from the same plan
    /// (unit-tested below), so the leader channel cannot drift from the
    /// virtual executor's submission path. `granted` is the cached-prefix
    /// skip the thread actually claimed (`== self.cached` on a full
    /// grant, the make_segment-equivalent case; less moves the shortfall
    /// from skip back into prefill without touching the span's end).
    fn to_segment(&self, granted: usize) -> Segment {
        let mut seg = Segment::from_parts(
            self.request,
            self.arrival,
            self.start - (self.cached - granted),
            self.prompt.len() - granted,
            self.decode_budget,
            self.emits_first,
            self.last_segment,
            self.gated,
        );
        seg.beta_dest = self.beta_dest;
        seg.interactive = self.interactive;
        seg.prefix_group = self.prefix_group;
        seg.shared_prefix = self.shared_prefix;
        seg.cached_prefix = granted;
        seg
    }
}

enum InstMsg {
    Segment(SegmentSpec),
    /// KV chunk for a gated segment (payload = k||v for the token range):
    /// a β awaiting its α handoff, or a fetch-gated α awaiting a remote
    /// prefix.
    Kv { key: u64, job: TransferJob, next_token: Option<i32> },
    /// Migration order from the leader: ship the first `tokens` KV rows
    /// of prefix group `group` (from this thread's engine-side pool) to
    /// the fetch-gated segment at `dest` — the live `Migration::Fetch`.
    /// The rows are copied out synchronously before the paced shipping
    /// thread detaches, so no source-side pin is needed; pool shortfalls
    /// ship zero rows (the lifecycle still ungates on the final chunk —
    /// a stub-engine approximation, see DESIGN.md §KV migration).
    Fetch { request: RequestId, group: u64, tokens: usize, dest: RemoteSeq },
    /// Begin draining: finish every resident segment, take no new ones
    /// (the leader already stopped placing here), then retire.
    Drain,
    /// Leader-side crash recovery re-placed this segment's request
    /// elsewhere: drop the orphan half (no-op if it already finished).
    Cancel { key: u64 },
    Shutdown,
}

enum UpMsg {
    Token { request: RequestId, arrival: f64, at: f64 },
    Done { request: RequestId },
    IterStats { instance: InstanceId, latency: f64 },
    /// An instance thread died (engine failure): its resident segments
    /// are lost and the leader must re-place their requests.
    Crashed { instance: InstanceId },
    /// A drained thread retired; `gated_in_place` counts the gated β
    /// segments that were resident when the drain started and finished in
    /// place (live drains do not re-place in-flight KV — module docs).
    Drained { instance: InstanceId, gated_in_place: usize },
}

/// Leader-side record of one dispatched-but-incomplete request — enough
/// to re-place it from scratch if an instance thread holding one of its
/// segments crashes (prompt ids included: token re-generation would
/// otherwise perturb the leader's RNG stream).
#[derive(Clone)]
struct Inflight {
    req: Request,
    prompt: Vec<i32>,
    alpha: RemoteSeq,
    beta: Option<RemoteSeq>,
}

/// State the instance threads publish and the leader (plus peer threads)
/// read — the live analogue of the cluster registry's shared view.
#[derive(Default)]
struct FleetShared {
    /// Latest per-instance load digest (BTreeMap: the leader's digest
    /// view is always in id order, like the virtual executor's).
    digests: Mutex<BTreeMap<InstanceId, LoadDigest>>,
    /// Instances whose engine finished loading + calibration — the live
    /// warm-up gate (the virtual executor models this as `cfg.warmup`).
    ready: Mutex<HashSet<InstanceId>>,
    /// Retirement stamps of drained instances (freezes their GPU-second
    /// meters).
    removed: Mutex<HashMap<InstanceId, f64>>,
    /// Peer senders for α→β KV forwarding.
    peers: Mutex<HashMap<InstanceId, mpsc::Sender<InstMsg>>>,
    /// Per-instance prefix-index views (cache-aware placement input),
    /// published by the instance threads when [`ServeConfig::cache`] is
    /// on. May lag the owning thread — the leader treats the matched
    /// length as a hint and the thread re-claims at accept time.
    prefix: Mutex<HashMap<InstanceId, PrefixView>>,
}

/// Everything needed to spawn one more instance thread mid-run.
#[derive(Clone)]
struct SpawnCtx {
    artifacts: String,
    slo: SloConfig,
    clock: WallClock,
    stop: Arc<AtomicBool>,
    calib: Arc<Mutex<Option<ProfileTable>>>,
    transfer: Arc<TransferEngine>,
    up: mpsc::Sender<UpMsg>,
    shared: Arc<FleetShared>,
    /// Mirror of [`ServeConfig::cache`]: threads enable their runtime's
    /// prefix index and publish views only when the leader routes with it.
    cache: bool,
}

/// Leader-side membership entry for one live instance.
struct LiveMember {
    id: InstanceId,
    tx: mpsc::Sender<InstMsg>,
    join: thread::JoinHandle<()>,
    draining: bool,
    /// Wall seconds (serving clock) when the thread was spawned —
    /// GPU-seconds accrue from here, engine bring-up included.
    added_at: f64,
}

/// The live fleet directory: the leader's mirror of `exec::Cluster` —
/// stable ids, spawn (add) / drain / retire lifecycle, GPU-second
/// accounting. See the module docs for the drain semantics difference
/// from the virtual executor.
struct LiveCluster {
    members: Vec<LiveMember>,
    next_id: u32,
    shared: Arc<FleetShared>,
}

impl LiveCluster {
    fn new(shared: Arc<FleetShared>) -> LiveCluster {
        LiveCluster { members: Vec::new(), next_id: 0, shared }
    }

    /// Spawn one instance thread; placeable once it publishes readiness
    /// (engine loaded + calibrated).
    fn add_instance(&mut self, ctx: &SpawnCtx) -> Result<InstanceId> {
        let id = InstanceId(self.next_id);
        self.next_id += 1;
        let (tx, rx) = mpsc::channel::<InstMsg>();
        self.shared.peers.lock().unwrap().insert(id, tx.clone());
        let c = ctx.clone();
        let join = thread::Builder::new()
            .name(format!("instance-{id}"))
            .spawn(move || {
                if let Err(e) = instance_loop(id, rx, &c) {
                    eprintln!("instance {id} failed: {e:#}");
                    // crash cleanup: instance_loop's own cleanup only runs
                    // on clean exits — pull the corpse out of the shared
                    // fleet view and stamp removal so the leader stops
                    // routing here, its GPU-second meter freezes, and the
                    // autoscaler's provisioning count frees up for a
                    // replacement; then tell the leader so it re-places
                    // the corpse's registered-but-incomplete requests
                    // (resident KV is lost — recovery restarts them from
                    // token 0 on the survivors)
                    c.shared.digests.lock().unwrap().remove(&id);
                    c.shared.ready.lock().unwrap().remove(&id);
                    c.shared.peers.lock().unwrap().remove(&id);
                    c.shared.prefix.lock().unwrap().remove(&id);
                    c.shared.removed.lock().unwrap().insert(id, c.clock.now());
                    c.up.send(UpMsg::Crashed { instance: id }).ok();
                }
            })
            .context("spawn instance")?;
        self.members.push(LiveMember { id, tx, join, draining: false, added_at: ctx.clock.now() });
        Ok(id)
    }

    /// Stop placing on `id` and tell its thread to finish + retire.
    /// Refused — with the reason, mirroring `exec::Cluster::drain` — when
    /// the member is unknown/draining or no *other* non-draining member
    /// is still alive (a crashed instance thread must not count as a
    /// survivor, or draining the last healthy one would leave the fleet
    /// unplaceable).
    fn drain(&mut self, id: InstanceId) -> Result<(), DrainError> {
        let survivors = self
            .members
            .iter()
            .filter(|m| m.id != id && !m.draining && !m.join.is_finished())
            .count();
        let Some(m) = self.members.iter_mut().find(|m| m.id == id) else {
            return Err(DrainError::UnknownInstance(id));
        };
        if m.draining {
            return Err(DrainError::WrongState(id));
        }
        if survivors == 0 {
            return Err(DrainError::LastPlaceable(id));
        }
        m.draining = true;
        m.tx.send(InstMsg::Drain).ok();
        Ok(())
    }

    /// Digest view for placement: ready, not draining, not retired — in
    /// id order (same dynamic view the virtual executor feeds policies).
    fn placeable_digests(&self) -> Vec<LoadDigest> {
        let ready = self.shared.ready.lock().unwrap();
        let removed = self.shared.removed.lock().unwrap();
        let digests = self.shared.digests.lock().unwrap();
        self.members
            .iter()
            .filter(|m| !m.draining && ready.contains(&m.id) && !removed.contains_key(&m.id))
            .filter_map(|m| digests.get(&m.id).copied())
            .collect()
    }

    fn send(&self, id: InstanceId, msg: InstMsg) {
        if let Some(m) = self.members.iter().find(|m| m.id == id) {
            m.tx.send(msg).ok();
        }
    }

    /// Fleet GPU-seconds by `now` (1 GPU per TinyQwen instance): drained
    /// members stop at their retirement stamp.
    fn gpu_seconds(&self, now: f64) -> f64 {
        let removed = self.shared.removed.lock().unwrap();
        self.members
            .iter()
            .map(|m| (removed.get(&m.id).copied().unwrap_or(now) - m.added_at).max(0.0))
            .sum()
    }

    fn shutdown(self) {
        for m in &self.members {
            m.tx.send(InstMsg::Shutdown).ok();
        }
        for m in self.members {
            m.join.join().ok();
        }
    }
}

/// Engine-side state of one live segment (the lifecycle state lives in
/// the shared [`InstanceRuntime`]; this is only what PJRT needs: the real
/// KV tensors, the token ids, and the decode continuation).
struct LiveState {
    kv: KvState,
    prompt: Vec<i32>,
    prefill_done: usize,
    /// Next token to feed when decoding.
    next_token: Option<i32>,
    /// KV chunk tokens received so far (β gating telemetry).
    received_tokens: usize,
    /// Leader-assigned id (for reverse lookup cleanup).
    leader_key: u64,
}

/// [`EventSink`] over the instance→leader channel: token emissions and
/// request completions stream to the leader's [`Collector`] — the same
/// sink interface the virtual executor satisfies with the collector
/// directly.
struct ChannelSink {
    up: mpsc::Sender<UpMsg>,
}

impl EventSink for ChannelSink {
    fn on_emit(&mut self, request: RequestId, arrival: f64, at: f64) {
        self.up.send(UpMsg::Token { request, arrival, at }).ok();
    }

    fn on_done(&mut self, request: RequestId) {
        self.up.send(UpMsg::Done { request }).ok();
    }
}

/// The live α→β transport: completion handoffs are recorded and then
/// shipped as *real* KV payloads on a detached thread ([`forward_kv`]),
/// so the lifecycle returns [`HandoffDisposition::Detached`] — α's arena
/// slot frees immediately and β readiness is signaled by the final chunk.
#[derive(Default)]
struct LiveTransport {
    pending: Vec<Handoff>,
}

impl LiveTransport {
    fn take_pending(&mut self) -> Vec<Handoff> {
        std::mem::take(&mut self.pending)
    }
}

impl Transport for LiveTransport {
    fn handoff(&mut self, _now: f64, h: Handoff) -> HandoffDisposition {
        self.pending.push(h);
        HandoffDisposition::Detached
    }
}

/// Serving report printed by `dynaserve serve`.
pub struct ServeReport {
    pub summary: Summary,
    /// Per-instance iteration counts, id order.
    pub iterations: Vec<(InstanceId, u64)>,
    pub mean_iter_latency: f64,
    pub transfer_chunks: u64,
    pub transfer_bytes: u64,
    pub wall_time: f64,
    /// Requests re-placed on survivors after an instance thread crashed.
    pub replaced_requests: u64,
    /// Gated β segments that finished in place during live drains (live
    /// drains do not re-place in-flight KV — module docs).
    pub drained_gated_in_place: u64,
}

impl ServeReport {
    pub fn print(&self) {
        let s = &self.summary;
        println!("── live serve report ──");
        println!(
            "requests completed: {}   output tokens: {}   wall time: {:.2}s",
            s.completed, s.total_tokens, self.wall_time
        );
        println!(
            "throughput: {:.1} tok/s   goodput: {:.1} tok/s   rps: {:.2}",
            s.throughput_tok_s, s.goodput_tok_s, s.rps
        );
        println!(
            "fleet: {:.1} GPU-seconds   goodput/GPU-s: {:.2}",
            s.gpu_seconds, s.goodput_per_gpu_s
        );
        println!(
            "TBT p50/p99: {:.1}/{:.1} ms   TTFT p50/p99: {:.0}/{:.0} ms   attainment: {:.1}%",
            s.p50_tbt * 1e3,
            s.p99_tbt * 1e3,
            s.p50_ttft * 1e3,
            s.p99_ttft * 1e3,
            s.attainment * 100.0
        );
        for (id, n) in &self.iterations {
            println!("instance {id}: {n} iterations");
        }
        println!(
            "kv transfer: {} chunks, {:.2} MB   mean iter latency: {:.2} ms",
            self.transfer_chunks,
            self.transfer_bytes as f64 / 1e6,
            self.mean_iter_latency * 1e3
        );
        if self.replaced_requests > 0 || self.drained_gated_in_place > 0 {
            println!(
                "fleet events: {} request(s) re-placed after crashes, {} gated β segment(s) \
                 finished in place during drains",
                self.replaced_requests, self.drained_gated_in_place
            );
        }
    }
}

/// The server facade's *stub-engine* executor: the same shared `exec`
/// lifecycle core the PJRT threads drive, in virtual time with the
/// modeled transport — deterministic, and bit-identical to the simulator
/// facade for the same config/policy. `rust/tests/parity.rs` pins this
/// facade (it must stay a thin instantiation of the one core — any
/// server-side lifecycle fork breaks the bit-identity there, scale
/// events and autoscaling included); the real thread wiring in
/// [`serve`]/`instance_loop` is pinned to the shared submission path by
/// the marshalling round-trip unit test below and executes only with
/// `--features pjrt`.
/// `experiments -- scenarios --executor live` routes through here.
pub fn virtual_executor(cfg: ExecConfig, policy: Box<dyn Policy>) -> VirtualExecutor {
    VirtualExecutor::new(cfg, policy)
}

/// Scale a sampled (P, D) shape to the tiny model's context budget.
/// Fixed shapes are taken as-is (just clamped); trace shapes divide by 64
/// so their prefill/decode *ratio* distribution survives the scaling.
fn scale_shape(kind: TraceKind, p: usize, d: usize, max_ctx: usize) -> (usize, usize) {
    let (p, d) = match kind {
        TraceKind::Fixed { .. } => (p.max(2), d.max(1)),
        _ => ((p / 64).clamp(4, 160), (d / 64).clamp(2, 64)),
    };
    let total = p + d;
    if total + 2 > max_ctx {
        let f = (max_ctx - 2) as f64 / total as f64;
        (((p as f64 * f) as usize).max(2), ((d as f64 * f) as usize).max(1))
    } else {
        (p, d)
    }
}

pub fn serve(cfg: ServeConfig) -> Result<ServeReport> {
    anyhow::ensure!(cfg.n_instances > 0, "need at least one instance");
    anyhow::ensure!(
        cfg.calibration_deadline_s > 0.0 && cfg.ready_deadline_s > 0.0,
        "calibration/ready deadlines must be positive"
    );
    anyhow::ensure!(
        cfg!(feature = "pjrt"),
        "`serve` drives the live PJRT engine; rebuild with `cargo build --features pjrt` \
         (the default build ships the stub backend — see README.md)"
    );
    let clock = WallClock::starting_now();

    // ── workload ────────────────────────────────────────────────────────
    let mut gen = WorkloadGen::new(
        TraceSampler::new(cfg.workload, cfg.seed),
        Box::new(PoissonArrivals::new(cfg.qps)),
        cfg.seed,
    );
    let horizon = cfg.requests as f64 / cfg.qps * 3.0 + 10.0;
    let mut requests: Vec<Request> = gen.generate(horizon);
    requests.truncate(cfg.requests);
    anyhow::ensure!(!requests.is_empty(), "no requests generated");
    let max_ctx = 256; // largest artifact capacity
    for r in requests.iter_mut() {
        let (p, d) = scale_shape(cfg.workload, r.prompt_len, r.decode_len, max_ctx);
        r.prompt_len = p;
        r.decode_len = d;
        r.predicted_decode = d;
    }

    // ── fleet bootstrap ─────────────────────────────────────────────────
    // Threads publish O(1) digests straight from their runtime — the same
    // load representation the simulator's arrival path feeds the policy —
    // into the shared fleet view, keyed by stable instance id.
    if cfg.migrate_preempt {
        eprintln!(
            "serve: decode-phase preemption is virtual-executor-only (ExecConfig::\
             migrate_preempt); ignoring --migrate-preempt on the live path"
        );
    }
    let shared = Arc::new(FleetShared::default());
    let live_link = LinkSpec { bandwidth: 2e9, latency: 20e-6 };
    let transfer = Arc::new(TransferEngine::new(live_link));
    let (up_tx, up_rx) = mpsc::channel::<UpMsg>();
    let stop = Arc::new(AtomicBool::new(false));
    // calibration profile shared by leader + instances (built by the
    // first instance to come up)
    let calib: Arc<Mutex<Option<ProfileTable>>> = Arc::new(Mutex::new(None));
    let spawn_ctx = SpawnCtx {
        artifacts: cfg.artifacts.clone(),
        slo: cfg.slo,
        clock,
        stop: stop.clone(),
        calib: calib.clone(),
        transfer: transfer.clone(),
        up: up_tx.clone(),
        shared: shared.clone(),
        cache: cfg.cache,
    };
    let mut fleet = LiveCluster::new(shared.clone());
    for _ in 0..cfg.n_instances {
        fleet.add_instance(&spawn_ctx)?;
    }

    // ── leader: wait for calibration, then schedule arrivals ───────────
    // Bounded wait: if every instance thread died (missing artifacts, engine
    // failure) the calibration slot never fills and we must error, not hang.
    let calib_deadline =
        Instant::now() + std::time::Duration::from_secs_f64(cfg.calibration_deadline_s);
    let profile = loop {
        if let Some(p) = calib.lock().unwrap().clone() {
            break p;
        }
        // A healthy instance thread never exits before calibration, so any
        // finished handle here means its engine failed to come up.
        anyhow::ensure!(
            !fleet.members.iter().any(|m| m.join.is_finished()),
            "an instance failed before calibration (artifacts missing or engine \
             failed; see per-instance errors above)"
        );
        anyhow::ensure!(
            Instant::now() < calib_deadline,
            "instances never finished calibration within {:.0}s",
            cfg.calibration_deadline_s
        );
        thread::sleep(std::time::Duration::from_millis(20));
    };
    let llm = LlmSpec::tinyqwen();
    // One dispatch path for both executors: the same Policy trait the
    // simulator's arrival handler calls (Algorithm 1 behind it).
    let mut policy = DynaServePolicy::new(GlobalConfig {
        kv_bytes_per_token: llm.kv_bytes_per_token(),
        predictor: PredictorConfig { slo: cfg.slo.tbt, ..Default::default() },
        min_span: 8,
        ..Default::default()
    });
    let mut autoscaler = cfg.autoscale.map(BandAutoscaler::new);
    // Fetch pricing on the live path: the same planner the virtual host
    // consults, over the live link and the live chunk size, with the CPU
    // instance's modeled prefill time as the recompute price. Only
    // planner-approved spans become remote offers — the scheduler then
    // weighs the discounted credit against local matches.
    let fetch_spec = InstanceSpec::new(GpuSpec::cpu_pjrt(), llm.clone(), 1);
    let fetch_planner =
        MigrationPlanner::new(live_link, 64, true, llm.kv_bytes_per_token());
    let mut migrated_bytes = 0.0f64;

    let mut key_alloc = 0u64;
    let mut rng = Rng::with_stream(cfg.seed, 0x70cc);
    let n_requests = requests.len();
    // dispatched-but-incomplete requests, keyed for crash recovery: if an
    // instance thread dies, every registered request with a segment on it
    // is re-placed on the survivors (the collect loop below)
    let mut inflight: HashMap<RequestId, Inflight> = HashMap::new();
    // metrics collector up front so each request's class / per-request SLO
    // targets register at submission — same scoring path as the simulator
    let mut collector = Collector::new(cfg.slo);
    // admission rejections: never dispatched, so the collect loop below
    // must not wait for their completions
    let mut rejected = 0usize;
    // serving clock starts after engine compilation/calibration
    let serve_start = clock.now();
    for req in &requests {
        // pace arrivals in real time
        let target = serve_start + req.arrival;
        let now = clock.now();
        if target > now {
            thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        // the threads publish O(1) digests — the same dynamic membership
        // view is used for autoscaling and for placement (recomputed only
        // when a directive changed the fleet)
        let mut loads = fleet.placeable_digests();
        // autoscale from the digest view — the live analogue of the
        // virtual executor's AutoscaleTick
        if let Some(scaler) = autoscaler.as_mut() {
            // hard cap mirroring the virtual executor's cfg.max_instances:
            // the scaler only sees placeable members, so without this an
            // engine bring-up slower than its cooldown could spawn
            // threads without bound
            let max_provisioned = scaler.cfg.max_instances;
            let directives = scaler.decide(clock.now(), &loads);
            let fleet_changed = !directives.is_empty();
            for d in directives {
                match d {
                    ScaleDirective::Add { count } => {
                        for _ in 0..count {
                            let provisioned = {
                                let removed = shared.removed.lock().unwrap();
                                fleet
                                    .members
                                    .iter()
                                    .filter(|m| !removed.contains_key(&m.id))
                                    .count()
                            };
                            if provisioned >= max_provisioned {
                                break;
                            }
                            let _ = fleet.add_instance(&spawn_ctx);
                        }
                    }
                    ScaleDirective::Drain { id } => {
                        // surface the refusal reason: an autoscaler drain
                        // bouncing off the last-placeable guard is normal,
                        // but the operator should see why nothing shrank
                        if let Err(e) = fleet.drain(id) {
                            eprintln!("autoscale: drain refused: {e}");
                        }
                    }
                }
            }
            if fleet_changed {
                loads = fleet.placeable_digests();
            }
        }
        // Bounded wait for readiness: right after calibration the first
        // thread may not have published its digest yet, and a freshly
        // scaled-up fleet may be all-warming for a moment.
        let ready_deadline =
            Instant::now() + std::time::Duration::from_secs_f64(cfg.ready_deadline_s);
        while loads.is_empty() {
            anyhow::ensure!(
                Instant::now() < ready_deadline,
                "no placeable instance within {:.0}s (fleet warming or fully draining)",
                cfg.ready_deadline_s
            );
            thread::sleep(std::time::Duration::from_millis(5));
            loads = fleet.placeable_digests();
        }
        // SLO-aware admission mirror of the virtual executor's gate
        // (`exec::host::on_arrival`): same predicate, same digest view the
        // policy is about to read — batch-class work bounces when every
        // placeable instance is saturated, so interactive arrivals keep
        // finding headroom instead of queueing behind a deferrable burst.
        if cfg.admission
            && req.slo.is_some()
            && !req.interactive()
            && fleet_saturated(&loads, PREFILL_BACKLOG_BUDGET)
        {
            collector.on_reject(req);
            rejected += 1;
            continue;
        }
        // Prefix-cache probe against the published per-instance views —
        // the live analogue of the virtual executor's arrival-time index
        // probe (`exec::host::on_arrival`): matched lengths feed the same
        // reuse-credited scoring, zero matches fall back to `place`.
        let matches: Vec<usize> = if cfg.cache {
            match crate::kv::prefix::lineage(req) {
                Some((group, _)) => {
                    let want = crate::kv::prefix::matchable_prompt(req);
                    let views = shared.prefix.lock().unwrap();
                    loads
                        .iter()
                        .map(|d| views.get(&d.id).map(|v| v.lookup(group, want)).unwrap_or(0))
                        .collect()
                }
                None => Vec::new(),
            }
        } else {
            Vec::new()
        };
        // Remote offers (live `Migration::Fetch` candidates): for each
        // placeable instance, the best *other* member's published view
        // match, offered only when the planner prices the transfer below
        // recomputing the extra span — the live mirror of the virtual
        // host's arrival-time offer loop. Deterministically iterates the
        // loads (member order), never the unordered view map.
        let mut remote: Vec<RemoteCredit> = Vec::new();
        let mut remote_src: Vec<InstanceId> = Vec::new();
        if cfg.migrate_fetch && !matches.is_empty() {
            let (group, _) = crate::kv::prefix::lineage(req).expect("matches imply lineage");
            let want = crate::kv::prefix::matchable_prompt(req);
            let views = shared.prefix.lock().unwrap();
            for (i, d) in loads.iter().enumerate() {
                let mut best = (0usize, d.id);
                for peer in &loads {
                    if peer.id == d.id {
                        continue;
                    }
                    let t = views.get(&peer.id).map(|v| v.lookup(group, want)).unwrap_or(0);
                    if t > best.0 {
                        best = (t, peer.id);
                    }
                }
                let extra = best.0.saturating_sub(matches[i]);
                let credit = if extra > 0
                    && fetch_planner.fetch_beats_recompute(extra, fetch_spec.prefill_time(extra))
                {
                    RemoteCredit {
                        tokens: best.0,
                        transfer_time: fetch_planner.transfer_time(best.0),
                    }
                } else {
                    RemoteCredit::default()
                };
                remote.push(credit);
                remote_src.push(best.1);
            }
        }
        let placement = if remote.iter().any(|r| r.tokens > 0) {
            policy.place_migrate(req, &loads, &matches, &remote, &profile)
        } else if matches.is_empty() {
            policy.place(req, &loads, &profile)
        } else {
            policy.place_cached(req, &loads, &matches, &profile)
        };
        // …and the same span clamping / flag derivation (exec::submit)
        let plan = plan_submission(&placement, req);
        // live hit accounting is placement-time: the thread may grant a
        // smaller skip than the credited match if its index moved since
        // the view was published (the virtual executor's same-event
        // probe→claim has no such gap)
        if cfg.cache && crate::kv::prefix::lineage(req).is_some() {
            collector.on_cache(req, plan.alpha.cached);
        }
        let prompt: Vec<i32> = (0..req.prompt_len)
            .map(|_| rng.range(1, llm.vocab as u64) as i32)
            .collect();
        key_alloc += 1;
        let alpha_key = key_alloc;
        let beta_info = plan.beta.as_ref().map(|bp| {
            key_alloc += 1;
            RemoteSeq::new(bp.instance, key_alloc)
        });
        // a nonzero fetch plan names the source: the offer row aligned
        // with the instance that won the head (never the head itself)
        let fetch_src = (plan.fetch_tokens > 0)
            .then(|| loads.iter().position(|d| d.id == plan.alpha.instance))
            .flatten()
            .and_then(|i| remote_src.get(i).copied())
            .filter(|src| *src != plan.alpha.instance);
        let arrival = clock.now();
        // register on the serving clock (token events use the same basis)
        collector.on_request(&Request { arrival, ..req.clone() });
        let alpha_spec = SegmentSpec::from_plan(
            alpha_key,
            req,
            arrival,
            &prompt,
            &plan.alpha,
            beta_info,
            fetch_src.is_some(),
            if fetch_src.is_some() { plan.alpha.cached } else { 0 },
        );
        fleet.send(plan.alpha.instance, InstMsg::Segment(alpha_spec));
        if let Some(src) = fetch_src {
            // ship the whole skipped span from the holder — it matched
            // `cached` tokens, so its pool covers the local overlap too
            fleet.send(
                src,
                InstMsg::Fetch {
                    request: req.id,
                    group: req.prefix_group.expect("fetch implies lineage"),
                    tokens: plan.alpha.cached,
                    dest: RemoteSeq::new(plan.alpha.instance, alpha_key),
                },
            );
            migrated_bytes += fetch_planner.bytes(plan.alpha.cached);
        }
        if let (Some(bp), Some(b)) = (&plan.beta, beta_info) {
            let beta_spec =
                SegmentSpec::from_plan(b.key, req, arrival, &prompt, bp, None, true, 0);
            fleet.send(b.instance, InstMsg::Segment(beta_spec));
        }
        inflight.insert(
            req.id,
            Inflight {
                req: Request { arrival, ..req.clone() },
                prompt,
                alpha: RemoteSeq::new(plan.alpha.instance, alpha_key),
                beta: beta_info,
            },
        );
    }

    // ── collect until all requests complete ─────────────────────────────
    let mut done = 0usize;
    let mut iter_counts: BTreeMap<InstanceId, u64> = BTreeMap::new();
    let mut iter_lat_sum = 0.0;
    let mut iter_lat_n = 0u64;
    let mut replaced_requests = 0u64;
    let mut drained_gated_in_place = 0u64;
    while done < n_requests - rejected {
        match up_rx.recv_timeout(std::time::Duration::from_secs(120)) {
            Ok(UpMsg::Token { request, arrival, at }) => collector.on_token(request, arrival, at),
            Ok(UpMsg::Done { request }) => {
                collector.on_complete(request);
                inflight.remove(&request);
                done += 1;
            }
            Ok(UpMsg::IterStats { instance, latency }) => {
                *iter_counts.entry(instance).or_default() += 1;
                iter_lat_sum += latency;
                iter_lat_n += 1;
            }
            Ok(UpMsg::Drained { instance, gated_in_place }) => {
                drained_gated_in_place += gated_in_place as u64;
                eprintln!(
                    "drain: instance {instance} retired; {gated_in_place} gated β segment(s) \
                     finished in place"
                );
            }
            Ok(UpMsg::Crashed { instance }) => {
                // dead-thread recovery: the corpse's resident KV is gone.
                // Cancel the surviving half of every affected request and
                // re-place the whole request from scratch with fresh keys
                // on the current placeable fleet. The rerun re-emits its
                // tokens from token 0; the collector scores the longer
                // token timeline — recovery latency shows up in the tail
                // metrics rather than in a separate counter here.
                let victims: Vec<RequestId> = inflight
                    .iter()
                    .filter(|(_, r)| {
                        r.alpha.instance == instance
                            || r.beta.map_or(false, |b| b.instance == instance)
                    })
                    .map(|(&id, _)| id)
                    .collect();
                if !victims.is_empty() {
                    eprintln!(
                        "recovery: instance {instance} crashed with {} in-flight request(s); \
                         re-placing on survivors",
                        victims.len()
                    );
                }
                for rid in victims {
                    let rec = inflight.get(&rid).cloned().expect("victim registered");
                    if rec.alpha.instance != instance {
                        fleet.send(rec.alpha.instance, InstMsg::Cancel { key: rec.alpha.key });
                    }
                    if let Some(b) = rec.beta {
                        if b.instance != instance {
                            fleet.send(b.instance, InstMsg::Cancel { key: b.key });
                        }
                    }
                    let loads = fleet.placeable_digests();
                    if loads.is_empty() {
                        // no survivor can take it — leave the request
                        // registered; the recv timeout surfaces the loss
                        eprintln!("recovery: no placeable instance for request {rid}");
                        continue;
                    }
                    let placement = policy.place(&rec.req, &loads, &profile);
                    let plan = plan_submission(&placement, &rec.req);
                    key_alloc += 1;
                    let alpha_key = key_alloc;
                    let beta_info = plan.beta.as_ref().map(|bp| {
                        key_alloc += 1;
                        RemoteSeq::new(bp.instance, key_alloc)
                    });
                    let alpha_spec = SegmentSpec::from_plan(
                        alpha_key,
                        &rec.req,
                        rec.req.arrival,
                        &rec.prompt,
                        &plan.alpha,
                        beta_info,
                        false,
                        0,
                    );
                    fleet.send(plan.alpha.instance, InstMsg::Segment(alpha_spec));
                    if let (Some(bp), Some(b)) = (&plan.beta, beta_info) {
                        let beta_spec = SegmentSpec::from_plan(
                            b.key,
                            &rec.req,
                            rec.req.arrival,
                            &rec.prompt,
                            bp,
                            None,
                            true,
                            0,
                        );
                        fleet.send(b.instance, InstMsg::Segment(beta_spec));
                    }
                    replaced_requests += 1;
                    if let Some(r) = inflight.get_mut(&rid) {
                        r.alpha = RemoteSeq::new(plan.alpha.instance, alpha_key);
                        r.beta = beta_info;
                    }
                }
            }
            Err(_) => anyhow::bail!("serve timed out waiting for tokens ({done}/{n_requests})"),
        }
    }
    stop.store(true, Ordering::SeqCst);
    let end = clock.now();
    // GPU-second accounting before shutdown: drained members froze at
    // their retirement stamps, the rest are charged to end-of-run
    let gpu_seconds = fleet.gpu_seconds(end);
    fleet.shutdown();
    let wall = end - serve_start;
    let stats = transfer.stats();
    Ok(ServeReport {
        summary: collector
            .summarize(wall)
            .with_fleet(gpu_seconds)
            .with_recovery(RecoveryStats { replaced_requests, ..Default::default() })
            .with_migration(migrated_bytes),
        iterations: iter_counts.into_iter().collect(),
        mean_iter_latency: if iter_lat_n == 0 { 0.0 } else { iter_lat_sum / iter_lat_n as f64 },
        transfer_chunks: stats.chunks.load(Ordering::Relaxed),
        transfer_bytes: stats.bytes.load(Ordering::Relaxed),
        wall_time: wall,
        replaced_requests,
        drained_gated_in_place,
    })
}

fn instance_loop(id: InstanceId, rx: mpsc::Receiver<InstMsg>, ctx: &SpawnCtx) -> Result<()> {
    let engine = Engine::load(&ctx.artifacts)?;
    let spec = InstanceSpec::new(GpuSpec::cpu_pjrt(), LlmSpec::tinyqwen(), 1);
    let clock = ctx.clock;

    // ── calibration: the first instance up seeds the shared profile ─────
    let mut profile = ProfileTable::seeded(&spec);
    {
        let mut guard = ctx.calib.lock().unwrap();
        if guard.is_none() {
            for (name, lat) in engine.calibrate(2)? {
                let b = engine.buckets().iter().find(|b| b.name == name).unwrap();
                let (plen, dnum) = if b.chunk == 1 { (0, b.batch) } else { (b.chunk, 0) };
                for _ in 0..12 {
                    profile.record(plen, b.capacity / 2, dnum, lat);
                }
            }
            *guard = Some(profile.clone());
        } else {
            profile = guard.clone().unwrap();
        }
    }

    let local = LocalScheduler::new(
        LocalConfig {
            slo: ctx.slo.tbt,
            max_decodes: engine.manifest.max_decode_batch(1).max(1),
            min_chunk: 8,
            max_prefill_tokens: 128,
            fixed_budget: None,
            slo_target: 0.85,
            priority: false,
        },
        profile,
    );

    // The shared lifecycle state machine — identical to the simulator's
    // per-instance core; this loop is just its PJRT executor.
    let mut runtime = InstanceRuntime::new(id, spec, local);
    if ctx.cache {
        runtime.enable_prefix_cache();
    }
    // Engine-side residency behind the runtime's prefix index (which
    // models token *counts* only): real KV tensors of recently retired
    // lineage-tagged segments, keyed by prefix group. Bounded FIFO — the
    // index is pressed independently, so accept-time claims are clamped
    // by what this pool actually still holds.
    const PREFIX_POOL_CAP: usize = 8;
    let mut prefix_pool: Vec<(u64, KvState)> = Vec::new();
    let mut live: HashMap<SeqKey, LiveState> = HashMap::new();
    let mut by_leader: HashMap<u64, SeqKey> = HashMap::new();
    let mut sink = ChannelSink { up: ctx.up.clone() };
    let mut transport = LiveTransport::default();
    let mut draining = false;
    // gated β segments resident when the drain order arrived — they
    // finish in place (their KV chunks keep arriving) and are reported
    // on retirement (the live counterpart of the virtual executor's
    // drain-time β re-placement diagnostics)
    let mut drain_gated_in_place = 0usize;

    // engine is up: publish readiness + an initial digest — the live
    // warm-up gate the leader's placeable view checks
    ctx.shared.digests.lock().unwrap().insert(id, runtime.digest());
    if ctx.cache {
        ctx.shared.prefix.lock().unwrap().insert(id, runtime.prefix_view());
    }
    ctx.shared.ready.lock().unwrap().insert(id);

    // removes this instance from the shared fleet view on any exit path;
    // `retired = true` additionally freezes its GPU-second meter (drain
    // completion, not fleet-wide shutdown)
    let cleanup = |retired: bool| {
        ctx.shared.digests.lock().unwrap().remove(&id);
        ctx.shared.ready.lock().unwrap().remove(&id);
        ctx.shared.peers.lock().unwrap().remove(&id);
        ctx.shared.prefix.lock().unwrap().remove(&id);
        if retired {
            ctx.shared.removed.lock().unwrap().insert(id, clock.now());
        }
    };

    loop {
        // drain control + transfer channels
        let mut accepted = false;
        loop {
            match rx.try_recv() {
                Ok(InstMsg::Segment(spec)) => {
                    // total context = unskipped start + prompt + decode
                    // (the prompt slice covers the leader-credited skip)
                    let total =
                        spec.start - spec.cached + spec.prompt.len() + spec.decode_budget + 1;
                    let cap = if total <= 128 { 128 } else { 256 };
                    // prefix-cache claim: re-probe the local index (the
                    // leader's view may lag), clamp by what the engine-
                    // side pool actually retains, then pin the grant.
                    // Fetch-gated segments import instead: their KV is
                    // not resident here — it arrives over the wire — so
                    // the skip is registered in the local index and
                    // pinned without an engine-side pool check.
                    let granted = match (ctx.cache && spec.cached > 0, spec.prefix_group) {
                        (true, Some(group)) if spec.fetch > 0 => {
                            let g = runtime.import_prefix(group, spec.cached, clock.now());
                            debug_assert_eq!(
                                g, spec.cached,
                                "fetch import pressed out of headroom at accept"
                            );
                            g
                        }
                        (true, Some(group)) => {
                            let pooled = prefix_pool
                                .iter()
                                .find(|(g, _)| *g == group)
                                .map(|(_, kv)| kv.len / PREFIX_BLOCK * PREFIX_BLOCK)
                                .unwrap_or(0);
                            let want = spec
                                .cached
                                .min(runtime.prefix_lookup(group, spec.cached))
                                .min(pooled);
                            runtime.claim_prefix(group, want, clock.now())
                        }
                        _ => 0,
                    };
                    // reconstruct the shared lifecycle segment (pinned to
                    // the virtual submission path by the round-trip test)
                    let key = runtime.accept(spec.to_segment(granted));
                    accepted = true;
                    by_leader.insert(spec.key, key);
                    let mut kv = engine.new_kv(cap);
                    // fetch-gated grants hold no local KV: the rows arrive
                    // as wire chunks and the final one activates the
                    // segment, exactly like a β handoff
                    if granted > 0 && spec.fetch == 0 {
                        // the claimed prefix reuses real KV from the pool
                        // instead of recomputing it
                        let m = &engine.manifest.model;
                        let src = prefix_pool
                            .iter()
                            .find(|(g, _)| Some(*g) == spec.prefix_group)
                            .map(|(_, kv)| kv)
                            .expect("claim clamped by pool residency");
                        copy_kv_prefix(
                            &mut kv,
                            src,
                            (m.n_layers, m.n_kv_heads, m.head_dim),
                            granted,
                        );
                        kv.len = granted;
                    }
                    live.insert(
                        key,
                        LiveState {
                            kv,
                            prompt: spec.prompt[granted..].to_vec(),
                            prefill_done: 0,
                            next_token: None,
                            received_tokens: 0,
                            leader_key: spec.key,
                        },
                    );
                }
                Ok(InstMsg::Kv { key, job, next_token }) => {
                    if let Some(&k) = by_leader.get(&key) {
                        inject_chunk(&engine, &mut runtime, &mut live, k, job, next_token);
                    }
                }
                Ok(InstMsg::Fetch { request, group, tokens, dest }) => {
                    // live Migration::Fetch source side: copy the pooled
                    // prefix rows out synchronously (no pin needed — the
                    // pool entry may be evicted the moment we return),
                    // then ship them through the same paced forward_kv
                    // path the α→β handoff uses. A pool shortfall ships
                    // zero rows for the missing tail: the stub engine
                    // tolerates the approximation and the destination
                    // still ungates on the final chunk.
                    let m = &engine.manifest.model;
                    let meta = (m.n_layers, m.n_kv_heads, m.head_dim);
                    let cap = if tokens <= 128 { 128 } else { 256 };
                    let mut out_kv = engine.new_kv(cap);
                    if let Some((_, src_kv)) =
                        prefix_pool.iter().find(|(g, _)| *g == group)
                    {
                        copy_kv_prefix(&mut out_kv, src_kv, meta, tokens.min(src_kv.len));
                    }
                    out_kv.len = tokens;
                    let transfer = ctx.transfer.clone();
                    let fwd_shared = ctx.shared.clone();
                    thread::spawn(move || {
                        forward_kv(
                            meta,
                            &transfer,
                            &fwd_shared,
                            &out_kv,
                            None,
                            request,
                            dest.instance,
                            dest.key,
                        );
                    });
                }
                Ok(InstMsg::Drain) => {
                    if !draining {
                        draining = true;
                        drain_gated_in_place = runtime.gated_count();
                    }
                }
                Ok(InstMsg::Cancel { key }) => {
                    // leader-side crash recovery re-placed this request:
                    // drop our orphan half (no-op if it already finished
                    // or its handoff shipped)
                    if let Some(k) = by_leader.remove(&key) {
                        runtime.evict(k);
                        live.remove(&k);
                    }
                }
                Ok(InstMsg::Shutdown) => {
                    cleanup(false);
                    return Ok(());
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    cleanup(false);
                    return Ok(());
                }
            }
        }
        if ctx.stop.load(Ordering::SeqCst) {
            cleanup(false);
            return Ok(());
        }
        // drain complete: every resident segment (gated βs included —
        // their KV chunks kept arriving above) has finished and shipped
        if draining && runtime.is_empty() {
            ctx.up
                .send(UpMsg::Drained { instance: id, gated_in_place: drain_gated_in_place })
                .ok();
            cleanup(true);
            return Ok(());
        }
        // publish accepted-but-not-yet-executed load immediately: a gated
        // β (awaiting its KV transfer) produces no iteration here, and
        // without this the leader would keep seeing this instance as idle
        // for the whole transfer — the sim's arrival path reads digests
        // that include such segments, so the live leader must too
        if accepted {
            ctx.shared.digests.lock().unwrap().insert(id, runtime.digest());
        }

        // ── compose the next batch through the shared lifecycle
        //    (Algorithm 2 over the runtime's FCFS order queue — the
        //    *same* code path the simulator uses) ─────────────────────
        let plan = runtime.plan_batch();
        if plan.is_empty() {
            thread::sleep(std::time::Duration::from_micros(300));
            continue;
        }

        let iter_start = Instant::now();
        let mut finished: Vec<SeqKey> = Vec::new();

        // decode sub-batches through the widest fitting bucket
        let mut pending: Vec<SeqKey> = plan
            .decodes
            .iter()
            .copied()
            .filter(|k| live.get(k).map(|s| s.next_token.is_some()).unwrap_or(false))
            .collect();
        while !pending.is_empty() {
            let max_ctx = pending
                .iter()
                .map(|k| live[k].kv.len + 1)
                .max()
                .unwrap();
            let bucket = engine
                .manifest
                .select_bucket(pending.len().min(8), 1, max_ctx)
                .or_else(|| engine.manifest.select_bucket(1, 1, max_ctx))
                .context("no decode bucket")?
                .clone();
            let take: Vec<SeqKey> = pending.drain(..pending.len().min(bucket.batch)).collect();
            // temporarily remove the states so we can hold disjoint &mut
            let mut taken: Vec<(SeqKey, LiveState)> = take
                .iter()
                .map(|k| (*k, live.remove(k).expect("decode state")))
                .collect();
            let tokens: Vec<[i32; 1]> =
                taken.iter().map(|(_, s)| [s.next_token.unwrap()]).collect();
            for (_, s) in taken.iter_mut() {
                if s.kv.capacity < bucket.capacity {
                    s.kv = engine.grow_kv(&s.kv, bucket.capacity);
                }
            }
            let mut refs: Vec<&mut KvState> =
                taken.iter_mut().map(|(_, s)| &mut s.kv).collect();
            let chunks: Vec<&[i32]> = tokens.iter().map(|t| t.as_slice()).collect();
            let out = engine.step(&bucket, &mut refs, &chunks)?;
            for (i, (k, mut s)) in taken.into_iter().enumerate() {
                let tok = Engine::argmax(&out.logits[i]);
                s.next_token = Some(tok);
                live.insert(k, s);
                if let Some(o) = runtime.apply_decode(k, clock.now()) {
                    if let Some((req, arr)) = o.emit {
                        sink.on_emit(req, arr, clock.now());
                    }
                    if o.completed {
                        finished.push(k);
                    }
                }
            }
        }

        // prefill chunks (one b=1 call per plan entry)
        for &(key, chunk_tokens) in &plan.prefill {
            let Some(s) = live.get_mut(&key) else { continue };
            let from = s.prefill_done;
            let n = chunk_tokens.min(128).min(s.prompt.len() - from);
            if n == 0 {
                continue;
            }
            let needed = s.kv.len + n;
            let bucket = engine
                .manifest
                .select_bucket(1, n, needed)
                .context("no prefill bucket")?
                .clone();
            if s.kv.capacity < bucket.capacity {
                s.kv = engine.grow_kv(&s.kv, bucket.capacity);
            }
            let toks = s.prompt[from..from + n].to_vec();
            let mut refs = [&mut s.kv];
            let out = engine.step(&bucket, &mut refs, &[&toks])?;
            s.prefill_done += n;
            if s.prefill_done == s.prompt.len() {
                // continuation token for the decode phase
                s.next_token = Some(Engine::argmax(&out.logits[0]));
            }
            if let Some(o) = runtime.apply_prefill(key, n, clock.now()) {
                if let Some((req, arr)) = o.emit {
                    sink.on_emit(req, arr, clock.now());
                }
                if o.completed {
                    finished.push(key);
                }
            }
        }

        let iter_latency = iter_start.elapsed().as_secs_f64();
        // RECORD into the shared profile under the plan's own query key,
        // exactly like the virtual executor
        runtime.record_iteration(&plan, iter_latency);
        ctx.up.send(UpMsg::IterStats { instance: id, latency: iter_latency }).ok();

        // completions through the shared lifecycle: final segments report
        // Done, α segments with a waiting β queue a live handoff
        for key in finished {
            let (hands_off, group) = runtime
                .get(key)
                .map(|s| (!s.last_segment && s.beta_dest.is_some(), s.prefix_group))
                .unwrap_or((false, None));
            runtime.complete_segment(key, clock.now(), &mut sink, &mut transport);
            if !hands_off {
                // retired outright — drop the engine-side state too (the
                // handoff case keeps it until the payload ships below)
                if let Some(st) = live.remove(&key) {
                    by_leader.remove(&st.leader_key);
                    if let Some(g) = group.filter(|_| ctx.cache) {
                        // the lifecycle just inserted this segment's
                        // residual into the prefix index — retain its
                        // real KV as the matching engine-side residency
                        prefix_pool.retain(|(pg, _)| *pg != g);
                        prefix_pool.push((g, st.kv));
                        if prefix_pool.len() > PREFIX_POOL_CAP {
                            prefix_pool.remove(0);
                        }
                    }
                }
            }
        }
        // ship queued handoffs: real KV payload to β, detached so pacing
        // never blocks this engine loop (the §4.3 overlap)
        for h in transport.take_pending() {
            let Some(st) = live.remove(&h.source) else { continue };
            by_leader.remove(&st.leader_key);
            let meta = (
                engine.manifest.model.n_layers,
                engine.manifest.model.n_kv_heads,
                engine.manifest.model.head_dim,
            );
            let transfer = ctx.transfer.clone();
            let shared = ctx.shared.clone();
            let dest = h.dest;
            thread::spawn(move || {
                forward_kv(
                    meta,
                    &transfer,
                    &shared,
                    &st.kv,
                    st.next_token,
                    h.request,
                    dest.instance,
                    dest.key,
                );
            });
        }

        // publish the O(1) load digest for the global scheduler
        ctx.shared.digests.lock().unwrap().insert(id, runtime.digest());
        if ctx.cache {
            // completions may have extended the prefix index — refresh
            // the leader's placement view alongside the digest
            ctx.shared.prefix.lock().unwrap().insert(id, runtime.prefix_view());
        }
    }
}

/// Ship a completed α segment's KV ([0, kv.len)) to the β instance in
/// chunks through the paced transfer engine, then the activation metadata
/// on the final chunk. Runs on a detached thread so pacing never blocks
/// the α instance's engine loop (the §4.3 overlap).
#[allow(clippy::too_many_arguments)]
fn forward_kv(
    (l, h, d): (usize, usize, usize),
    transfer: &TransferEngine,
    shared: &Arc<FleetShared>,
    kv: &KvState,
    next_token: Option<i32>,
    request: RequestId,
    b_inst: InstanceId,
    b_key: u64,
) {
    let chunk_tokens = 64;
    let total = kv.len;
    let dest = {
        let peers = shared.peers.lock().unwrap();
        match peers.get(&b_inst) {
            Some(d) => d.clone(),
            None => return,
        }
    };
    let mut start = 0;
    while start < total {
        let end = (start + chunk_tokens).min(total);
        let payload = extract_kv_range(kv, (l, h, d), start, end);
        let (tx, rx) = mpsc::channel();
        transfer.push(
            TransferJob {
                request,
                token_range: (start, end),
                payload,
                last: end == total,
            },
            tx,
        );
        // rendezvous: the paced engine delivers when the link would have
        if let Ok(job) = rx.recv() {
            let next = (end == total).then(|| next_token.unwrap_or(0));
            dest.send(InstMsg::Kv { key: b_key, job, next_token: next }).ok();
        }
        start = end;
    }
}

/// Extract k||v for token range [a, b) from a KvState (layer-major rows).
fn extract_kv_range(kv: &KvState, (l, h, d): (usize, usize, usize), a: usize, b: usize) -> Vec<f32> {
    let s = kv.capacity;
    let n = b - a;
    let mut out = Vec::with_capacity(2 * l * h * n * d);
    for src in [&kv.k, &kv.v] {
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * h) + hi) * s * d;
                out.extend_from_slice(&src[base + a * d..base + b * d]);
            }
        }
    }
    out
}

/// Copy k||v rows for tokens [0, n) from a retained pool entry into a
/// fresh sequence's KV (both layer-major, with their own capacities) —
/// the engine-side half of a prefix-cache claim: the claimed tokens'
/// KV is reused instead of recomputed.
fn copy_kv_prefix(dst: &mut KvState, src: &KvState, (l, h, d): (usize, usize, usize), n: usize) {
    let (dc, sc) = (dst.capacity, src.capacity);
    for (dbuf, sbuf) in [(&mut dst.k, &src.k), (&mut dst.v, &src.v)] {
        for li in 0..l {
            for hi in 0..h {
                let db = ((li * h) + hi) * dc * d;
                let sb = ((li * h) + hi) * sc * d;
                dbuf[db..db + n * d].copy_from_slice(&sbuf[sb..sb + n * d]);
            }
        }
    }
}

/// Inject a received chunk into a β sequence's KV; activate on the final
/// chunk (setting the continuation token for pure-decode β segments and
/// marking the runtime segment ready — the live analogue of the virtual
/// executor's `SeqReady` event).
fn inject_chunk(
    engine: &Engine,
    runtime: &mut InstanceRuntime,
    live: &mut HashMap<SeqKey, LiveState>,
    key: SeqKey,
    job: TransferJob,
    next_token: Option<i32>,
) {
    let Some(seq_end) = runtime.get(key).map(|s| s.end_exec) else { return };
    let Some(st) = live.get_mut(&key) else { return };
    let (a, b) = job.token_range;
    let m = &engine.manifest.model;
    let (l, h, d) = (m.n_layers, m.n_kv_heads, m.head_dim);
    let needed = seq_end + 1;
    if st.kv.capacity < needed.max(b) {
        st.kv = engine.grow_kv(&st.kv, 256);
    }
    let s = st.kv.capacity;
    let n = b - a;
    let half = job.payload.len() / 2;
    for (dst, payload) in
        [(&mut st.kv.k, &job.payload[..half]), (&mut st.kv.v, &job.payload[half..])]
    {
        let mut p = 0;
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * h) + hi) * s * d;
                dst[base + a * d..base + b * d].copy_from_slice(&payload[p..p + n * d]);
                p += n * d;
            }
        }
    }
    st.received_tokens += n;
    if job.last {
        st.kv.len = b;
        // pure-decode β continues from α's last generated token; β with a
        // prefill remainder derives its own continuation from that prefill
        if st.prompt.is_empty() {
            st.next_token = next_token;
        }
        runtime.mark_ready(key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ProfileTable;
    use crate::exec::submit::make_segment;

    /// The live half of the sim↔live parity contract (`tests/parity.rs`
    /// pins the facade wiring; this pins the real server marshalling):
    /// the leader serializes each clamped `SegmentPlan` into a channel
    /// `SegmentSpec`, and the instance thread reconstructs the lifecycle
    /// `Segment` from it. That round-trip must land on exactly the
    /// segment the virtual executor builds from the same plan — modulo
    /// `track_kv_history`, which only the modeled transport consumes —
    /// so a drift in either direction (flags, spans, budgets, prompt
    /// slicing) fails here instead of surfacing as a live-only metrics
    /// bug, the class of divergence that motivated the exec/ layer.
    #[test]
    fn segment_spec_round_trip_matches_virtual_submission() {
        let spec = InstanceSpec::new(GpuSpec::a100(), LlmSpec::qwen25_14b(), 1);
        let profile = ProfileTable::seeded(&spec);
        let mut policy = DynaServePolicy::new(GlobalConfig::default());
        let loads: Vec<LoadDigest> = (0..2).map(|i| LoadDigest::idle(InstanceId(i))).collect();
        let cases = vec![
            Request::new(1, 0.0, 100, 50),
            Request::new(2, 0.5, 2000, 400),
            {
                // over-prediction: β may be cancelled by true-length clamping
                let mut r = Request::new(3, 1.0, 800, 10);
                r.predicted_decode = 600;
                r
            },
            {
                // decode-heavy: the split lands past the prefill boundary
                let mut r = Request::new(4, 1.5, 64, 900);
                r.predicted_decode = 900;
                r
            },
        ];
        for req in cases {
            let placement = policy.place(&req, &loads, &profile);
            let plan = plan_submission(&placement, &req);
            let prompt: Vec<i32> = (0..req.prompt_len as i32).collect();
            let beta_info = plan.beta.as_ref().map(|bp| RemoteSeq::new(bp.instance, 2u64));

            let alpha_spec = SegmentSpec::from_plan(
                1,
                &req,
                req.arrival,
                &prompt,
                &plan.alpha,
                beta_info,
                false,
                0,
            );
            let mut want_alpha = make_segment(&req, &plan.alpha, false, false);
            want_alpha.beta_dest = beta_info;
            assert_eq!(
                alpha_spec.to_segment(plan.alpha.cached),
                want_alpha,
                "req {}: α marshalling drifted from the virtual submission path",
                req.id
            );
            assert_eq!(alpha_spec.prompt.len(), plan.alpha.prefill, "req {}: α prompt slice", req.id);

            if let Some(bp) = &plan.beta {
                let beta_spec =
                    SegmentSpec::from_plan(2, &req, req.arrival, &prompt, bp, None, true, 0);
                let want_beta = make_segment(&req, bp, true, false);
                assert_eq!(
                    beta_spec.to_segment(0),
                    want_beta,
                    "req {}: β marshalling drifted from the virtual submission path",
                    req.id
                );
                assert_eq!(beta_spec.prompt.len(), bp.prefill, "req {}: β prompt slice", req.id);
                // the reconstructed β is gated exactly like the sim's
                assert!(!beta_spec.to_segment(0).ready);
            }
        }
    }

    /// Cache-credited specs extend the round-trip contract: a full grant
    /// reconstructs exactly the segment `make_segment` builds from the
    /// same cached plan, and a partial grant (the thread's index moved
    /// since the leader's view was published) moves the shortfall from
    /// skip back into prefill without touching the span's end.
    #[test]
    fn cached_segment_spec_round_trip_and_partial_grant() {
        let spec = InstanceSpec::new(GpuSpec::a100(), LlmSpec::qwen25_14b(), 1);
        let profile = ProfileTable::seeded(&spec);
        let mut policy = DynaServePolicy::new(GlobalConfig::default());
        let loads: Vec<LoadDigest> = (0..2).map(|i| LoadDigest::idle(InstanceId(i))).collect();
        let req = Request::new(5, 2.0, 4 * PREFIX_BLOCK, 40).with_prefix(7, 3 * PREFIX_BLOCK);
        let matches = vec![3 * PREFIX_BLOCK, 0];
        let placement = policy.place_cached(&req, &loads, &matches, &profile);
        let plan = plan_submission(&placement, &req);
        assert_eq!(plan.alpha.instance, InstanceId(0), "reuse credit routes α to the match");
        assert_eq!(plan.alpha.cached, 3 * PREFIX_BLOCK);
        let prompt: Vec<i32> = (0..req.prompt_len as i32).collect();
        let alpha_spec =
            SegmentSpec::from_plan(1, &req, req.arrival, &prompt, &plan.alpha, None, false, 0);
        assert_eq!(
            alpha_spec.prompt.len(),
            plan.alpha.prefill + plan.alpha.cached,
            "spec ships the skipped region too (threads may grant less than the hint)"
        );
        let want = make_segment(&req, &plan.alpha, false, false);
        assert_eq!(alpha_spec.to_segment(plan.alpha.cached), want, "full grant");
        let partial = plan.alpha.cached - PREFIX_BLOCK;
        let seg = alpha_spec.to_segment(partial);
        assert_eq!(seg.cached_prefix, partial);
        assert_eq!(seg.work.context, want.work.context - PREFIX_BLOCK);
        assert_eq!(seg.work.prefill_remaining, want.work.prefill_remaining + PREFIX_BLOCK);
        assert_eq!(seg.end_exec, want.end_exec, "the grant never moves the span's end");
        let zero = alpha_spec.to_segment(0);
        assert_eq!(zero.work.context, 0, "zero grant prefills from token 0");
        assert_eq!(zero.work.prefill_remaining, alpha_spec.prompt.len());
        // fetch-gated marshalling: the same plan shipped as a remote fetch
        // reconstructs exactly the gated α the virtual executor builds —
        // inactive until the final wire chunk marks it ready
        let fetch_spec = SegmentSpec::from_plan(
            9,
            &req,
            req.arrival,
            &prompt,
            &plan.alpha,
            None,
            true,
            plan.alpha.cached,
        );
        let want_gated = make_segment(&req, &plan.alpha, true, false);
        let got = fetch_spec.to_segment(plan.alpha.cached);
        assert_eq!(got, want_gated, "fetch-gated α marshalling");
        assert!(!got.ready, "fetch-gated α waits for the wire");
    }

    /// The live drain guard mirrors the virtual cluster's: the directory
    /// refuses to drain its last non-draining member, and GPU-seconds
    /// freeze at the retirement stamp a drained thread publishes.
    #[test]
    fn live_cluster_drain_guard_and_gpu_seconds() {
        let shared = Arc::new(FleetShared::default());
        let mut fleet = LiveCluster::new(shared.clone());
        // stub members: channels with no thread behind them
        for i in 0..2u32 {
            let (tx, rx) = mpsc::channel::<InstMsg>();
            std::mem::forget(rx); // keep the channel open without a thread
            let join = thread::Builder::new().spawn(|| {}).unwrap();
            fleet.members.push(LiveMember {
                id: InstanceId(i),
                tx,
                join,
                draining: false,
                added_at: 1.0,
            });
            fleet.next_id = i + 1;
        }
        assert_eq!(fleet.drain(InstanceId(1)), Ok(()));
        assert_eq!(
            fleet.drain(InstanceId(1)),
            Err(DrainError::WrongState(InstanceId(1))),
            "already draining"
        );
        assert_eq!(
            fleet.drain(InstanceId(0)),
            Err(DrainError::LastPlaceable(InstanceId(0))),
            "last non-draining member"
        );
        assert_eq!(
            fleet.drain(InstanceId(9)),
            Err(DrainError::UnknownInstance(InstanceId(9))),
            "unknown id"
        );
        // a drained thread stamps its retirement; the meter freezes there
        shared.removed.lock().unwrap().insert(InstanceId(1), 5.0);
        assert!((fleet.gpu_seconds(11.0) - (10.0 + 4.0)).abs() < 1e-9);
    }
}
