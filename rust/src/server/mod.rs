//! Live serving path: real token generation through the AOT-compiled
//! TinyQwen artifacts on PJRT CPU instances.
//!
//! Topology: a leader thread runs the global scheduler (Algorithm 1) over
//! live instance snapshots and dispatches α/β micro-request segments to
//! instance threads over channels. Each instance thread owns a PJRT
//! [`Engine`], runs the *same* [`LocalScheduler`] (Algorithm 2) as the
//! simulator — its profile table calibrated online from measured step
//! latencies — and streams KV chunks to β instances through the paced
//! [`TransferEngine`] (§4.3). Python is nowhere on this path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::local::{DecodeEntry, PrefillEntry};
use crate::coordinator::predictor::PredictorConfig;
use crate::coordinator::{
    GlobalConfig, GlobalScheduler, InstanceSnapshot, LoadDigest, LocalConfig, LocalScheduler,
    ProfileTable, WorkItem,
};
use crate::core::{Request, RequestId};
use crate::costmodel::{GpuSpec, InstanceSpec, LlmSpec};
use crate::kv::{LinkSpec, TransferEngine, TransferJob};
use crate::metrics::{Collector, SloConfig, Summary};
use crate::runtime::{Engine, KvState};
use crate::util::rng::Rng;
use crate::workload::{PoissonArrivals, TraceKind, WorkloadGen, TraceSampler};

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts: String,
    pub n_instances: usize,
    pub requests: usize,
    pub qps: f64,
    pub workload: TraceKind,
    pub seed: u64,
    pub slo: SloConfig,
}

/// One placed segment, as sent to an instance thread.
struct SegmentSpec {
    key: u64,
    request: RequestId,
    arrival: f64,
    /// Prompt token ids this segment must prefill (span ∩ [0, P)).
    prompt: Vec<i32>,
    /// Context length at which this segment starts.
    start: usize,
    /// Decode tokens to generate.
    decode_budget: usize,
    emits_first: bool,
    last_segment: bool,
    /// Forward KV + generation state here when done (β instance index, β key).
    beta_dest: Option<(usize, u64)>,
    /// β only: waits for KV; activated by the final chunk.
    gated: bool,
}

enum InstMsg {
    Segment(SegmentSpec),
    /// KV chunk for a gated β segment (payload = k||v for the token range).
    Kv { key: u64, job: TransferJob, next_token: Option<i32> },
    Shutdown,
}

enum UpMsg {
    Token { request: RequestId, arrival: f64, at: f64 },
    Done { request: RequestId },
    IterStats { instance: usize, latency: f64 },
}

struct LiveSeq {
    spec: SegmentSpec,
    kv: KvState,
    prefill_done: usize,
    emitted: usize,
    /// Next token to feed when decoding.
    next_token: Option<i32>,
    ready: bool,
    /// KV chunks received so far (β gating).
    received_tokens: usize,
}

/// Serving report printed by `dynaserve serve`.
pub struct ServeReport {
    pub summary: Summary,
    pub iterations: Vec<u64>,
    pub mean_iter_latency: f64,
    pub transfer_chunks: u64,
    pub transfer_bytes: u64,
    pub wall_time: f64,
}

impl ServeReport {
    pub fn print(&self) {
        let s = &self.summary;
        println!("── live serve report ──");
        println!(
            "requests completed: {}   output tokens: {}   wall time: {:.2}s",
            s.completed, s.total_tokens, self.wall_time
        );
        println!(
            "throughput: {:.1} tok/s   goodput: {:.1} tok/s   rps: {:.2}",
            s.throughput_tok_s, s.goodput_tok_s, s.rps
        );
        println!(
            "TBT p50/p99: {:.1}/{:.1} ms   TTFT p50/p99: {:.0}/{:.0} ms   attainment: {:.1}%",
            s.p50_tbt * 1e3,
            s.p99_tbt * 1e3,
            s.p50_ttft * 1e3,
            s.p99_ttft * 1e3,
            s.attainment * 100.0
        );
        for (i, n) in self.iterations.iter().enumerate() {
            println!("instance {i}: {n} iterations");
        }
        println!(
            "kv transfer: {} chunks, {:.2} MB   mean iter latency: {:.2} ms",
            self.transfer_chunks,
            self.transfer_bytes as f64 / 1e6,
            self.mean_iter_latency * 1e3
        );
    }
}

/// Scale a sampled (P, D) shape to the tiny model's context budget.
/// Fixed shapes are taken as-is (just clamped); trace shapes divide by 64
/// so their prefill/decode *ratio* distribution survives the scaling.
fn scale_shape(kind: TraceKind, p: usize, d: usize, max_ctx: usize) -> (usize, usize) {
    let (p, d) = match kind {
        TraceKind::Fixed { .. } => (p.max(2), d.max(1)),
        _ => ((p / 64).clamp(4, 160), (d / 64).clamp(2, 64)),
    };
    let total = p + d;
    if total + 2 > max_ctx {
        let f = (max_ctx - 2) as f64 / total as f64;
        (((p as f64 * f) as usize).max(2), ((d as f64 * f) as usize).max(1))
    } else {
        (p, d)
    }
}

pub fn serve(cfg: ServeConfig) -> Result<ServeReport> {
    anyhow::ensure!(cfg.n_instances > 0, "need at least one instance");
    anyhow::ensure!(
        cfg!(feature = "pjrt"),
        "`serve` drives the live PJRT engine; rebuild with `cargo build --features pjrt` \
         (the default build ships the stub backend — see README.md)"
    );
    let epoch = Instant::now();
    let t = |i: Instant| i.duration_since(epoch).as_secs_f64();

    // ── workload ────────────────────────────────────────────────────────
    let mut gen = WorkloadGen::new(
        TraceSampler::new(cfg.workload, cfg.seed),
        Box::new(PoissonArrivals::new(cfg.qps)),
        cfg.seed,
    );
    let horizon = cfg.requests as f64 / cfg.qps * 3.0 + 10.0;
    let mut requests: Vec<Request> = gen.generate(horizon);
    requests.truncate(cfg.requests);
    anyhow::ensure!(!requests.is_empty(), "no requests generated");
    let max_ctx = 256; // largest artifact capacity
    for r in requests.iter_mut() {
        let (p, d) = scale_shape(cfg.workload, r.prompt_len, r.decode_len, max_ctx);
        r.prompt_len = p;
        r.decode_len = d;
        r.predicted_decode = d;
    }

    // ── instances ───────────────────────────────────────────────────────
    let snapshots: Arc<Mutex<Vec<InstanceSnapshot>>> = Arc::new(Mutex::new(
        (0..cfg.n_instances)
            .map(|id| InstanceSnapshot { id, ..Default::default() })
            .collect(),
    ));
    let transfer = Arc::new(TransferEngine::new(LinkSpec { bandwidth: 2e9, latency: 20e-6 }));
    let (up_tx, up_rx) = mpsc::channel::<UpMsg>();
    let stop = Arc::new(AtomicBool::new(false));

    let mut inst_txs = Vec::new();
    let mut joins = Vec::new();
    // calibration profile shared by leader + instances (built by instance 0)
    let calib: Arc<Mutex<Option<ProfileTable>>> = Arc::new(Mutex::new(None));

    for id in 0..cfg.n_instances {
        let (tx, rx) = mpsc::channel::<InstMsg>();
        inst_txs.push(tx);
        let up = up_tx.clone();
        let snaps = snapshots.clone();
        let dir = cfg.artifacts.clone();
        let slo = cfg.slo;
        let stop = stop.clone();
        let calib = calib.clone();
        let transfer = transfer.clone();
        let inst_txs_for_fw: Arc<Mutex<Vec<mpsc::Sender<InstMsg>>>> =
            Arc::new(Mutex::new(Vec::new()));
        joins.push((
            inst_txs_for_fw.clone(),
            thread::Builder::new()
                .name(format!("instance-{id}"))
                .spawn(move || {
                    if let Err(e) = instance_loop(
                        id, &dir, rx, up, snaps, slo, epoch, stop, calib, transfer,
                        inst_txs_for_fw,
                    ) {
                        eprintln!("instance {id} failed: {e:#}");
                    }
                })
                .context("spawn instance")?,
        ));
    }
    // give every instance a way to forward KV to its peers
    for (fw, _) in &joins {
        *fw.lock().unwrap() = inst_txs.clone();
    }

    // ── leader: wait for calibration, then schedule arrivals ───────────
    // Bounded wait: if every instance thread died (missing artifacts, engine
    // failure) the calibration slot never fills and we must error, not hang.
    let calib_deadline = Instant::now() + std::time::Duration::from_secs(300);
    let profile = loop {
        if let Some(p) = calib.lock().unwrap().clone() {
            break p;
        }
        // A healthy instance thread never exits before calibration, so any
        // finished handle here means its engine failed to come up.
        anyhow::ensure!(
            !joins.iter().any(|(_, j)| j.is_finished()),
            "an instance failed before calibration (artifacts missing or engine \
             failed; see per-instance errors above)"
        );
        anyhow::ensure!(
            Instant::now() < calib_deadline,
            "instances never finished calibration within 300s"
        );
        thread::sleep(std::time::Duration::from_millis(20));
    };
    let llm = LlmSpec::tinyqwen();
    let mut global = GlobalScheduler::new(GlobalConfig {
        kv_bytes_per_token: llm.kv_bytes_per_token(),
        predictor: PredictorConfig { slo: cfg.slo.tbt, ..Default::default() },
        min_span: 8,
        ..Default::default()
    });

    let mut key_alloc = 0u64;
    let mut rng = Rng::with_stream(cfg.seed, 0x70cc);
    let n_requests = requests.len();
    // metrics collector up front so each request's class / per-request SLO
    // targets register at submission — same scoring path as the simulator
    let mut collector = Collector::new(cfg.slo);
    // serving clock starts after engine compilation/calibration
    let serve_start = t(Instant::now());
    for req in &requests {
        // pace arrivals in real time
        let target = serve_start + req.arrival;
        let now = t(Instant::now());
        if target > now {
            thread::sleep(std::time::Duration::from_secs_f64(target - now));
        }
        // reduce the published snapshots to O(1) digests — same hot path
        // as the simulator, and no per-request snapshot clone
        let loads: Vec<LoadDigest> = snapshots
            .lock()
            .unwrap()
            .iter()
            .map(LoadDigest::from_snapshot)
            .collect();
        let out = global.schedule(req, &loads, &profile);
        let (a, b) = out.decision.to_micro_requests(req);
        let prompt: Vec<i32> = (0..req.prompt_len)
            .map(|_| rng.range(1, llm.vocab as u64) as i32)
            .collect();
        let l_proc = req.prompt_len + req.decode_len - 1;
        let (a, b) = match (a, b) {
            (Some(a), b) => (a, b),
            (None, Some(b)) => (crate::core::MicroRequest { role: crate::core::Role::Alpha, ..b }, None),
            _ => unreachable!(),
        };
        let s = a.end.min(l_proc);
        let beta = b.filter(|b| b.start < l_proc);
        key_alloc += 1;
        let alpha_key = key_alloc;
        let beta_info = beta.as_ref().map(|b| {
            key_alloc += 1;
            (b.instance, key_alloc)
        });
        let arrival = t(Instant::now());
        // register on the serving clock (token events use the same basis)
        collector.on_request(&Request { arrival, ..req.clone() });
        let alpha_spec = SegmentSpec {
            key: alpha_key,
            request: req.id,
            arrival,
            prompt: prompt[..s.min(req.prompt_len)].to_vec(),
            start: 0,
            decode_budget: s.saturating_sub(req.prompt_len),
            emits_first: s >= req.prompt_len,
            last_segment: beta_info.is_none(),
            beta_dest: beta_info,
            gated: false,
        };
        inst_txs[a.instance]
            .send(InstMsg::Segment(alpha_spec))
            .ok();
        if let (Some(bmr), Some((b_inst, b_key))) = (&beta, beta_info) {
            let beta_spec = SegmentSpec {
                key: b_key,
                request: req.id,
                arrival,
                prompt: prompt[bmr.start.min(req.prompt_len)..req.prompt_len].to_vec(),
                start: bmr.start,
                decode_budget: l_proc.saturating_sub(bmr.start.max(req.prompt_len)),
                emits_first: bmr.start < req.prompt_len,
                last_segment: true,
                beta_dest: None,
                gated: true,
            };
            inst_txs[b_inst].send(InstMsg::Segment(beta_spec)).ok();
        }
    }

    // ── collect until all requests complete ─────────────────────────────
    let mut done = 0usize;
    let mut iter_counts = vec![0u64; cfg.n_instances];
    let mut iter_lat_sum = 0.0;
    let mut iter_lat_n = 0u64;
    while done < n_requests {
        match up_rx.recv_timeout(std::time::Duration::from_secs(120)) {
            Ok(UpMsg::Token { request, arrival, at }) => collector.on_token(request, arrival, at),
            Ok(UpMsg::Done { request }) => {
                collector.on_complete(request);
                done += 1;
            }
            Ok(UpMsg::IterStats { instance, latency }) => {
                iter_counts[instance] += 1;
                iter_lat_sum += latency;
                iter_lat_n += 1;
            }
            Err(_) => anyhow::bail!("serve timed out waiting for tokens ({done}/{n_requests})"),
        }
    }
    stop.store(true, Ordering::SeqCst);
    for tx in &inst_txs {
        tx.send(InstMsg::Shutdown).ok();
    }
    for (_, j) in joins {
        j.join().ok();
    }
    let wall = t(Instant::now()) - serve_start;
    let stats = transfer.stats();
    Ok(ServeReport {
        summary: collector.summarize(wall),
        iterations: iter_counts,
        mean_iter_latency: if iter_lat_n == 0 { 0.0 } else { iter_lat_sum / iter_lat_n as f64 },
        transfer_chunks: stats.chunks.load(Ordering::Relaxed),
        transfer_bytes: stats.bytes.load(Ordering::Relaxed),
        wall_time: wall,
    })
}

#[allow(clippy::too_many_arguments)]
fn instance_loop(
    id: usize,
    artifacts: &str,
    rx: mpsc::Receiver<InstMsg>,
    up: mpsc::Sender<UpMsg>,
    snapshots: Arc<Mutex<Vec<InstanceSnapshot>>>,
    slo: SloConfig,
    epoch: Instant,
    stop: Arc<AtomicBool>,
    calib: Arc<Mutex<Option<ProfileTable>>>,
    transfer: Arc<TransferEngine>,
    peer_txs: Arc<Mutex<Vec<mpsc::Sender<InstMsg>>>>,
) -> Result<()> {
    let engine = Engine::load(artifacts)?;
    let now = |x: Instant| x.duration_since(epoch).as_secs_f64();

    // ── calibration: instance 0 seeds the shared profile table ──────────
    let mut profile = ProfileTable::seeded(&InstanceSpec::new(
        GpuSpec::cpu_pjrt(),
        LlmSpec::tinyqwen(),
        1,
    ));
    {
        let mut guard = calib.lock().unwrap();
        if guard.is_none() {
            for (name, lat) in engine.calibrate(2)? {
                let b = engine.buckets().iter().find(|b| b.name == name).unwrap();
                let (plen, dnum) = if b.chunk == 1 { (0, b.batch) } else { (b.chunk, 0) };
                for _ in 0..12 {
                    profile.record(plen, b.capacity / 2, dnum, lat);
                }
            }
            *guard = Some(profile.clone());
        } else {
            profile = guard.clone().unwrap();
        }
    }

    let mut local = LocalScheduler::new(
        LocalConfig {
            slo: slo.tbt,
            max_decodes: engine.manifest.max_decode_batch(1).max(1),
            min_chunk: 8,
            max_prefill_tokens: 128,
            fixed_budget: None,
            slo_target: 0.85,
        },
        profile,
    );

    let mut seqs: HashMap<u64, LiveSeq> = HashMap::new();
    let mut order: Vec<u64> = Vec::new();

    loop {
        // drain control + transfer channels
        loop {
            match rx.try_recv() {
                Ok(InstMsg::Segment(spec)) => {
                    let key = spec.key;
                    let cap = if spec.start + spec.prompt.len() + spec.decode_budget + 1 <= 128 {
                        128
                    } else {
                        256
                    };
                    let gated = spec.gated;
                    seqs.insert(
                        key,
                        LiveSeq {
                            kv: engine.new_kv(cap),
                            prefill_done: 0,
                            emitted: 0,
                            next_token: None,
                            ready: !gated,
                            received_tokens: 0,
                            spec,
                        },
                    );
                    order.push(key);
                }
                Ok(InstMsg::Kv { key, job, next_token }) => {
                    inject_chunk(&engine, &mut seqs, key, job, next_token);
                }
                Ok(InstMsg::Shutdown) => return Ok(()),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return Ok(()),
            }
        }
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }

        // ── compose the next batch (Algorithm 2, the *same* code path the
        //    simulator uses) ────────────────────────────────────────────
        let mut decodes = Vec::new();
        let mut prefills = Vec::new();
        for key in &order {
            let s = &seqs[key];
            if !s.ready {
                continue;
            }
            let pf_left = s.spec.prompt.len() - s.prefill_done;
            if pf_left > 0 {
                prefills.push(PrefillEntry {
                    key: *key,
                    remaining: pf_left,
                    context: s.kv.len,
                });
            } else if s.emitted < s.spec.decode_budget && s.next_token.is_some() {
                decodes.push(DecodeEntry { key: *key, context: s.kv.len });
            }
        }
        let plan = local.next_batch(&decodes, &prefills);
        if plan.is_empty() {
            thread::sleep(std::time::Duration::from_micros(300));
            continue;
        }

        let iter_start = Instant::now();
        let mut finished: Vec<u64> = Vec::new();

        // decode sub-batches through the widest fitting bucket
        let mut pending: Vec<u64> = plan.decodes.clone();
        while !pending.is_empty() {
            let max_ctx = pending
                .iter()
                .map(|k| seqs[k].kv.len + 1)
                .max()
                .unwrap();
            let bucket = engine
                .manifest
                .select_bucket(pending.len().min(8), 1, max_ctx)
                .or_else(|| engine.manifest.select_bucket(1, 1, max_ctx))
                .context("no decode bucket")?
                .clone();
            let take: Vec<u64> = pending.drain(..pending.len().min(bucket.batch)).collect();
            // temporarily remove the sequences so we can hold disjoint &mut
            let mut taken: Vec<(u64, LiveSeq)> = take
                .iter()
                .map(|k| (*k, seqs.remove(k).expect("decode seq")))
                .collect();
            let tokens: Vec<[i32; 1]> =
                taken.iter().map(|(_, s)| [s.next_token.unwrap()]).collect();
            for (_, s) in taken.iter_mut() {
                if s.kv.capacity < bucket.capacity {
                    s.kv = engine.grow_kv(&s.kv, bucket.capacity);
                }
            }
            let mut refs: Vec<&mut KvState> =
                taken.iter_mut().map(|(_, s)| &mut s.kv).collect();
            let chunks: Vec<&[i32]> = tokens.iter().map(|t| t.as_slice()).collect();
            let out = engine.step(&bucket, &mut refs, &chunks)?;
            for (i, (k, mut s)) in taken.into_iter().enumerate() {
                let tok = Engine::argmax(&out.logits[i]);
                s.emitted += 1;
                s.next_token = Some(tok);
                up.send(UpMsg::Token {
                    request: s.spec.request,
                    arrival: s.spec.arrival,
                    at: now(Instant::now()),
                })
                .ok();
                if s.emitted >= s.spec.decode_budget {
                    finished.push(k);
                }
                seqs.insert(k, s);
            }
        }

        // prefill chunks (one b=1 call per plan entry)
        for (key, chunk_tokens) in &plan.prefill {
            let s = seqs.get_mut(key).unwrap();
            let from = s.prefill_done;
            let n = (*chunk_tokens).min(128).min(s.spec.prompt.len() - from);
            if n == 0 {
                continue;
            }
            let needed = s.kv.len + n;
            let bucket = engine
                .manifest
                .select_bucket(1, n, needed)
                .context("no prefill bucket")?
                .clone();
            if s.kv.capacity < bucket.capacity {
                s.kv = engine.grow_kv(&s.kv, bucket.capacity);
            }
            let toks = s.spec.prompt[from..from + n].to_vec();
            let mut refs = [&mut s.kv];
            let out = engine.step(&bucket, &mut refs, &[&toks])?;
            s.prefill_done += n;
            if s.prefill_done == s.spec.prompt.len() {
                let tok = Engine::argmax(&out.logits[0]);
                s.next_token = Some(tok);
                if s.spec.emits_first {
                    s.emitted_first(&up, now(Instant::now()));
                }
                if s.spec.decode_budget == 0 {
                    finished.push(*key);
                }
            }
        }

        let iter_latency = iter_start.elapsed().as_secs_f64();
        local.record_execution(iter_latency);
        up.send(UpMsg::IterStats { instance: id, latency: iter_latency }).ok();

        // completions: forward KV to β (detached, overlapped with compute)
        // or finish the request
        for key in finished {
            let s = seqs.remove(&key).expect("finished seq");
            order.retain(|k| *k != key);
            if s.spec.last_segment {
                up.send(UpMsg::Done { request: s.spec.request }).ok();
            }
            if let Some((b_inst, b_key)) = s.spec.beta_dest {
                let meta = (
                    engine.manifest.model.n_layers,
                    engine.manifest.model.n_kv_heads,
                    engine.manifest.model.head_dim,
                );
                let transfer = transfer.clone();
                let peers = peer_txs.clone();
                thread::spawn(move || {
                    forward_kv(meta, &transfer, &peers, &s, b_inst, b_key);
                });
            }
        }

        // publish a load snapshot for the global scheduler
        {
            let mut snaps = snapshots.lock().unwrap();
            snaps[id].work = order
                .iter()
                .filter_map(|k| seqs.get(k))
                .map(|s| WorkItem {
                    prefill_remaining: s.spec.prompt.len() - s.prefill_done,
                    context: s.kv.len,
                    decode_remaining: s.spec.decode_budget - s.emitted,
                })
                .collect();
        }
    }
}

impl LiveSeq {
    fn emitted_first(&mut self, up: &mpsc::Sender<UpMsg>, at: f64) {
        self.emitted += 0; // first token is "free" w.r.t. the decode budget
        up.send(UpMsg::Token { request: self.spec.request, arrival: self.spec.arrival, at })
            .ok();
    }
}

/// Ship a completed α segment's KV ([0, kv.len)) to the β instance in
/// chunks through the paced transfer engine, then the activation metadata
/// on the final chunk. Runs on a detached thread so pacing never blocks
/// the α instance's engine loop (the §4.3 overlap).
fn forward_kv(
    (l, h, d): (usize, usize, usize),
    transfer: &TransferEngine,
    peers: &Arc<Mutex<Vec<mpsc::Sender<InstMsg>>>>,
    seq: &LiveSeq,
    b_inst: usize,
    b_key: u64,
) {
    let chunk_tokens = 64;
    let total = seq.kv.len;
    let dest = {
        let peers = peers.lock().unwrap();
        match peers.get(b_inst) {
            Some(d) => d.clone(),
            None => return,
        }
    };
    let mut start = 0;
    while start < total {
        let end = (start + chunk_tokens).min(total);
        let payload = extract_kv_range(&seq.kv, (l, h, d), start, end);
        let (tx, rx) = mpsc::channel();
        transfer.push(
            TransferJob {
                request: seq.spec.request,
                token_range: (start, end),
                payload,
                last: end == total,
            },
            tx,
        );
        // rendezvous: the paced engine delivers when the link would have
        if let Ok(job) = rx.recv() {
            let next = (end == total).then(|| seq.next_token.unwrap_or(0));
            dest.send(InstMsg::Kv { key: b_key, job, next_token: next }).ok();
        }
        start = end;
    }
}

/// Extract k||v for token range [a, b) from a KvState (layer-major rows).
fn extract_kv_range(kv: &KvState, (l, h, d): (usize, usize, usize), a: usize, b: usize) -> Vec<f32> {
    let s = kv.capacity;
    let n = b - a;
    let mut out = Vec::with_capacity(2 * l * h * n * d);
    for src in [&kv.k, &kv.v] {
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * h) + hi) * s * d;
                out.extend_from_slice(&src[base + a * d..base + b * d]);
            }
        }
    }
    out
}

/// Inject a received chunk into a β sequence's KV; activate on the final
/// chunk (setting the continuation token for pure-decode β segments).
fn inject_chunk(
    engine: &Engine,
    seqs: &mut HashMap<u64, LiveSeq>,
    key: u64,
    job: TransferJob,
    next_token: Option<i32>,
) {
    let Some(seq) = seqs.get_mut(&key) else { return };
    let (a, b) = job.token_range;
    let m = &engine.manifest.model;
    let (l, h, d) = (m.n_layers, m.n_kv_heads, m.head_dim);
    let needed = seq.spec.start + seq.spec.prompt.len() + seq.spec.decode_budget + 1;
    if seq.kv.capacity < needed.max(b) {
        seq.kv = engine.grow_kv(&seq.kv, 256);
    }
    let s = seq.kv.capacity;
    let n = b - a;
    let half = job.payload.len() / 2;
    for (dst, payload) in
        [(&mut seq.kv.k, &job.payload[..half]), (&mut seq.kv.v, &job.payload[half..])]
    {
        let mut p = 0;
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * h) + hi) * s * d;
                dst[base + a * d..base + b * d].copy_from_slice(&payload[p..p + n * d]);
                p += n * d;
            }
        }
    }
    seq.received_tokens += n;
    if job.last {
        seq.kv.len = b;
        // pure-decode β continues from α's last generated token; β with a
        // prefill remainder derives its own continuation from that prefill
        if seq.spec.prompt.is_empty() {
            seq.next_token = next_token;
        }
        seq.ready = true;
    }
}
