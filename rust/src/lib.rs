//! # DynaServe (reproduction)
//!
//! Unified and elastic execution for dynamic disaggregated LLM serving.
//!
//! A three-layer Rust + JAX + Pallas reproduction of the DynaServe paper
//! (Ruan et al., 2025). This crate is Layer 3: the serving coordinator —
//! the micro-request abstraction, the two-level (global + local) scheduling
//! framework, chunk-based KV transfer, the PD-colocation and
//! PD-disaggregation baselines, the analytical A100 cost model and
//! discrete-event simulator used to reproduce the paper's evaluation, and
//! a live serving path that executes a real (tiny) transformer through
//! AOT-compiled XLA artifacts via PJRT (behind the `pjrt` cargo feature;
//! the default build substitutes a compile-clean stub backend).
//!
//! Layers 1 and 2 (the Pallas attention kernels and the JAX model) live in
//! `python/compile/` and run only at build time (`make artifacts`); Python
//! is never on the request path.
//!
//! See DESIGN.md for the architecture and the per-experiment index, and
//! EXPERIMENTS.md for measured reproductions of every paper table/figure.

pub mod baselines;
pub mod coordinator;
pub mod core;
pub mod costmodel;
pub mod exec;
pub mod experiments;
pub mod kv;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;
