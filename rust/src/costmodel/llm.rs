//! LLM architecture specifications (Qwen-2.5 series — the paper's models —
//! plus the TinyQwen model the live PJRT path actually executes).

/// Transformer architecture parameters the cost model needs.
#[derive(Debug, Clone, PartialEq)]
pub struct LlmSpec {
    pub name: String,
    pub n_params: f64,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    /// Bytes per weight/KV element (2 = bf16, 4 = f32).
    pub dtype_bytes: usize,
}

impl LlmSpec {
    pub fn weight_bytes(&self) -> f64 {
        self.n_params * self.dtype_bytes as f64
    }

    /// KV bytes appended per token: 2 (K and V) · layers · kv_heads · head_dim.
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.n_layers * self.n_kv_heads * self.head_dim * self.dtype_bytes) as f64
    }

    /// Qwen-2.5-14B-Instruct (48 layers, GQA 40/8, d=5120).
    pub fn qwen25_14b() -> LlmSpec {
        LlmSpec {
            name: "qwen2.5-14b".to_string(),
            n_params: 14.7e9,
            n_layers: 48,
            d_model: 5120,
            n_q_heads: 40,
            n_kv_heads: 8,
            head_dim: 128,
            vocab: 152_064,
            dtype_bytes: 2,
        }
    }

    /// Qwen-2.5-32B (64 layers, GQA 40/8, d=5120).
    pub fn qwen25_32b() -> LlmSpec {
        LlmSpec {
            name: "qwen2.5-32b".to_string(),
            n_params: 32.5e9,
            n_layers: 64,
            d_model: 5120,
            n_q_heads: 40,
            n_kv_heads: 8,
            head_dim: 128,
            vocab: 152_064,
            dtype_bytes: 2,
        }
    }

    /// Qwen-2.5-72B (80 layers, GQA 64/8, d=8192).
    pub fn qwen25_72b() -> LlmSpec {
        LlmSpec {
            name: "qwen2.5-72b".to_string(),
            n_params: 72.7e9,
            n_layers: 80,
            d_model: 8192,
            n_q_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            vocab: 152_064,
            dtype_bytes: 2,
        }
    }

    /// Llama-3.1-8B — used by the paper's Figure 6 microbenchmark.
    pub fn llama31_8b() -> LlmSpec {
        LlmSpec {
            name: "llama3.1-8b".to_string(),
            n_params: 8.0e9,
            n_layers: 32,
            d_model: 4096,
            n_q_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            vocab: 128_256,
            dtype_bytes: 2,
        }
    }

    /// The ~1M-param model the live PJRT path serves (must mirror
    /// python/compile/model.py's ModelConfig).
    pub fn tinyqwen() -> LlmSpec {
        LlmSpec {
            name: "tinyqwen".to_string(),
            n_params: 1_049_728.0,
            n_layers: 4,
            d_model: 128,
            n_q_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            vocab: 256,
            dtype_bytes: 4,
        }
    }

    pub fn by_name(name: &str) -> Option<LlmSpec> {
        match name {
            "qwen2.5-14b" | "14b" => Some(Self::qwen25_14b()),
            "qwen2.5-32b" | "32b" => Some(Self::qwen25_32b()),
            "qwen2.5-72b" | "72b" => Some(Self::qwen25_72b()),
            "llama3.1-8b" | "8b" => Some(Self::llama31_8b()),
            "tinyqwen" => Some(Self::tinyqwen()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_qwen14b() {
        // 2 · 48 layers · 8 kv heads · 128 dim · 2 bytes = 196 608 B/token
        assert_eq!(LlmSpec::qwen25_14b().kv_bytes_per_token(), 196_608.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(LlmSpec::by_name("14b").unwrap().n_layers, 48);
        assert_eq!(LlmSpec::by_name("72b").unwrap().d_model, 8192);
        assert!(LlmSpec::by_name("gpt-x").is_none());
    }

    #[test]
    fn weights_fit_assumptions() {
        // 14B bf16 weights ≈ 29.4 GB — fits one A100 with room for KV.
        let w = LlmSpec::qwen25_14b().weight_bytes();
        assert!(w > 25e9 && w < 35e9);
    }
}
