//! Analytical GPU cost model — the simulation substrate standing in for the
//! paper's A100 testbed (DESIGN.md §1).
//!
//! The model is a roofline: an iteration's latency is
//! `max(compute_time, memory_time) + fixed_overhead`, where compute counts
//! transformer FLOPs (2·N per token plus attention's 4·L·d_attn·ctx) and
//! memory counts weight reads (once per iteration) plus KV-cache traffic.
//! This reproduces the regimes the paper's analysis rests on: prefill is
//! compute-bound (latency ∝ chunk tokens), decode is memory-bound (latency ≈
//! weights/HBM-bandwidth + KV reads), and mixing them trades TBT for MFU
//! exactly as in Figure 6.

pub mod gpu;
pub mod llm;

pub use gpu::GpuSpec;
pub use llm::LlmSpec;

/// Composition of one engine iteration (one "hybrid batch" in the paper).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchShape {
    /// New prompt tokens processed this iteration (sum over prefill chunks).
    pub prefill_tokens: usize,
    /// Average context already resident for those prefill tokens (affects
    /// attention FLOPs and KV reads of the chunk).
    pub prefill_ctx: usize,
    /// Number of sequences advancing one decode token.
    pub decode_reqs: usize,
    /// Average context length of the decoding sequences.
    pub decode_ctx: usize,
}

impl BatchShape {
    pub fn total_tokens(&self) -> usize {
        self.prefill_tokens + self.decode_reqs
    }

    pub fn is_empty(&self) -> bool {
        self.total_tokens() == 0
    }
}

/// Cost breakdown for one iteration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterationCost {
    pub latency: f64,
    pub compute_time: f64,
    pub memory_time: f64,
    pub flops: f64,
    pub bytes: f64,
    /// Model FLOPs utilization over the iteration.
    pub mfu: f64,
}

/// An instance = one model replica on `tp` GPUs (tensor parallel).
#[derive(Debug, Clone)]
pub struct InstanceSpec {
    pub gpu: GpuSpec,
    pub llm: LlmSpec,
    pub tp: usize,
}

impl InstanceSpec {
    pub fn new(gpu: GpuSpec, llm: LlmSpec, tp: usize) -> Self {
        assert!(tp >= 1);
        InstanceSpec { gpu, llm, tp }
    }

    /// Effective peak FLOP/s across the TP group (with a mild scaling
    /// penalty per doubling, matching measured TP efficiency on NVLink).
    pub fn peak_flops(&self) -> f64 {
        let penalty = 0.95_f64.powf((self.tp as f64).log2());
        self.gpu.peak_flops * self.tp as f64 * penalty
    }

    pub fn hbm_bw(&self) -> f64 {
        self.gpu.hbm_bw * self.tp as f64
    }

    /// HBM capacity available for KV cache after weights + activations.
    pub fn kv_capacity_bytes(&self) -> f64 {
        let total = self.gpu.hbm_capacity * self.tp as f64;
        let weights = self.llm.weight_bytes();
        (total * 0.94 - weights - self.gpu.activation_reserve).max(0.0)
    }

    /// Max KV tokens resident.
    pub fn kv_capacity_tokens(&self) -> usize {
        (self.kv_capacity_bytes() / self.llm.kv_bytes_per_token()) as usize
    }

    /// Per-iteration TP synchronization cost (allreduce per layer pair).
    fn tp_overhead(&self) -> f64 {
        if self.tp == 1 {
            0.0
        } else {
            2.0 * self.llm.n_layers as f64 * self.gpu.allreduce_latency
        }
    }

    /// Compute efficiency ramp: small token counts underutilize the SMs.
    /// eff(t) = eff_max · t / (t + t_half). Calibrated so a 2048-token
    /// prefill of Qwen-14B on one A100 takes ≈ 230 ms (paper Table 1
    /// regime) and an 8-way decode batch stays memory-bound.
    fn compute_eff(&self, tokens: usize) -> f64 {
        let t = tokens as f64;
        self.gpu.eff_max * t / (t + self.gpu.eff_half_sat)
    }

    /// Roofline cost of one iteration.
    pub fn iteration_cost(&self, shape: &BatchShape) -> IterationCost {
        if shape.is_empty() {
            return IterationCost {
                latency: self.gpu.kernel_overhead,
                compute_time: 0.0,
                memory_time: 0.0,
                flops: 0.0,
                bytes: 0.0,
                mfu: 0.0,
            };
        }
        let llm = &self.llm;
        let tokens = shape.total_tokens() as f64;

        // Linear (MLP + projections) FLOPs: 2·N_params per token.
        let mut flops = 2.0 * llm.n_params * tokens;
        // Attention FLOPs: 4·d_attn·ctx per token per layer (QKᵀ + PV).
        let d_attn = (llm.n_q_heads * llm.head_dim) as f64;
        let prefill_avg_ctx = shape.prefill_ctx as f64 + shape.prefill_tokens as f64 / 2.0;
        flops += 4.0
            * llm.n_layers as f64
            * d_attn
            * (shape.prefill_tokens as f64 * prefill_avg_ctx
                + shape.decode_reqs as f64 * shape.decode_ctx as f64);

        // Memory traffic: weights once per iteration + KV reads + KV writes.
        let kv_tok = llm.kv_bytes_per_token();
        let kv_read = kv_tok
            * (shape.decode_reqs as f64 * shape.decode_ctx as f64
                + shape.prefill_tokens as f64 * prefill_avg_ctx / 64.0);
        // (prefill KV reads amortize across the chunk's parallel FLOPs —
        //  the /64 reflects flash-attention block reuse.)
        let kv_write = kv_tok * tokens;
        let bytes = llm.weight_bytes() + kv_read + kv_write;

        let compute_time = flops / (self.peak_flops() * self.compute_eff(shape.total_tokens()));
        let memory_time = bytes / self.hbm_bw();
        let latency =
            compute_time.max(memory_time) + self.gpu.kernel_overhead + self.tp_overhead();
        IterationCost {
            latency,
            compute_time,
            memory_time,
            flops,
            bytes,
            mfu: flops / (latency * self.peak_flops()),
        }
    }

    /// Time to prefill `n` prompt tokens in SLO-agnostic full-size chunks —
    /// used for the "balanced decode curve" of Figure 3 and the predictor's
    /// cold-start seeding.
    pub fn prefill_time(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        let chunk = 2048.min(n.max(1));
        let iters = n.div_ceil(chunk);
        let mut t = 0.0;
        for i in 0..iters {
            let this = chunk.min(n - i * chunk);
            t += self
                .iteration_cost(&BatchShape {
                    prefill_tokens: this,
                    prefill_ctx: i * chunk,
                    decode_reqs: 0,
                    decode_ctx: 0,
                })
                .latency;
        }
        t
    }

    /// Mean per-token prefill cost (GPU-seconds/token) over an `n`-token
    /// prompt — the unit price `experiments cache` uses to convert the
    /// aggregate prefill-tokens-saved of a prefix-cache run into
    /// estimated GPU-seconds of compute saved (DESIGN.md §Prefix cache).
    /// An estimate by construction: the skipped spans are the *heads* of
    /// their prompts, so pricing them at the mean over a representative
    /// prompt length slightly overstates the saving (early chunks attend
    /// over less context and are cheaper).
    pub fn prefill_cost_per_token(&self, n: usize) -> f64 {
        let n = n.max(1);
        self.prefill_time(n) / n as f64
    }

    /// Time for one decode token at context `ctx` in a batch of `n` decodes.
    pub fn decode_step_time(&self, n: usize, ctx: usize) -> f64 {
        self.iteration_cost(&BatchShape {
            prefill_tokens: 0,
            prefill_ctx: 0,
            decode_reqs: n,
            decode_ctx: ctx,
        })
        .latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100_14b() -> InstanceSpec {
        InstanceSpec::new(GpuSpec::a100(), LlmSpec::qwen25_14b(), 1)
    }

    #[test]
    fn prefill_is_compute_bound() {
        let inst = a100_14b();
        let c = inst.iteration_cost(&BatchShape {
            prefill_tokens: 2048,
            prefill_ctx: 0,
            decode_reqs: 0,
            decode_ctx: 0,
        });
        assert!(c.compute_time > c.memory_time, "{c:?}");
        // Qwen-14B 2048-token chunk on one A100: paper regime is ~200-350ms
        assert!(c.latency > 0.15 && c.latency < 0.45, "latency={}", c.latency);
    }

    #[test]
    fn prefill_cost_per_token_prices_the_cache_saving() {
        let inst = a100_14b();
        let per_tok = inst.prefill_cost_per_token(2048);
        assert!(per_tok > 0.0 && per_tok.is_finite());
        assert!((per_tok - inst.prefill_time(2048) / 2048.0).abs() < 1e-15);
        // longer prompts attend over more context: mean unit price rises
        assert!(inst.prefill_cost_per_token(8192) > per_tok);
        // degenerate input is defined (no division by zero)
        assert!(inst.prefill_cost_per_token(0).is_finite());
    }

    #[test]
    fn decode_is_memory_bound() {
        let inst = a100_14b();
        let c = inst.iteration_cost(&BatchShape {
            prefill_tokens: 0,
            prefill_ctx: 0,
            decode_reqs: 8,
            decode_ctx: 1024,
        });
        assert!(c.memory_time > c.compute_time, "{c:?}");
        // ≈ weights(28GB)/2TB/s ≈ 14ms + KV + overhead, well under 100ms SLO
        assert!(c.latency > 0.010 && c.latency < 0.060, "latency={}", c.latency);
    }

    #[test]
    fn mixed_batch_latency_between_pure_ones() {
        let inst = a100_14b();
        let decode_only = inst.decode_step_time(16, 512);
        let mixed = inst
            .iteration_cost(&BatchShape {
                prefill_tokens: 512,
                prefill_ctx: 0,
                decode_reqs: 16,
                decode_ctx: 512,
            })
            .latency;
        assert!(mixed > decode_only);
        // adding prefill tokens increases MFU
        let mfu_d = inst
            .iteration_cost(&BatchShape {
                prefill_tokens: 0,
                prefill_ctx: 0,
                decode_reqs: 16,
                decode_ctx: 512,
            })
            .mfu;
        let mfu_m = inst
            .iteration_cost(&BatchShape {
                prefill_tokens: 512,
                prefill_ctx: 0,
                decode_reqs: 16,
                decode_ctx: 512,
            })
            .mfu;
        assert!(mfu_m > mfu_d * 2.0, "mfu decode-only={mfu_d} mixed={mfu_m}");
    }

    #[test]
    fn latency_monotone_in_prefill_tokens() {
        let inst = a100_14b();
        let mut last = 0.0;
        for p in [0, 128, 256, 512, 1024, 2048] {
            let l = inst
                .iteration_cost(&BatchShape {
                    prefill_tokens: p,
                    prefill_ctx: 0,
                    decode_reqs: 8,
                    decode_ctx: 512,
                })
                .latency;
            assert!(l >= last, "p={p}: {l} < {last}");
            last = l;
        }
    }

    #[test]
    fn tp_scales_throughput() {
        let one = a100_14b();
        let two = InstanceSpec::new(GpuSpec::a100(), LlmSpec::qwen25_14b(), 2);
        let shape = BatchShape {
            prefill_tokens: 2048,
            prefill_ctx: 0,
            decode_reqs: 0,
            decode_ctx: 0,
        };
        let l1 = one.iteration_cost(&shape).latency;
        let l2 = two.iteration_cost(&shape).latency;
        assert!(l2 < l1 && l2 > l1 / 2.0, "l1={l1} l2={l2}");
    }

    #[test]
    fn kv_capacity_positive_and_sane() {
        let inst = a100_14b();
        let cap = inst.kv_capacity_tokens();
        // 80GB - 28GB weights ≈ 47GB usable; ÷196KB/token ≈ 240k tokens
        assert!(cap > 100_000 && cap < 400_000, "cap={cap}");
    }

    #[test]
    fn larger_models_slower() {
        let m14 = a100_14b();
        let m72 = InstanceSpec::new(GpuSpec::a100(), LlmSpec::qwen25_72b(), 4);
        let shape = BatchShape {
            prefill_tokens: 1024,
            prefill_ctx: 0,
            decode_reqs: 0,
            decode_ctx: 0,
        };
        assert!(m72.iteration_cost(&shape).latency > m14.iteration_cost(&shape).latency);
    }

    #[test]
    fn mfu_bounded() {
        let inst = a100_14b();
        for p in [64, 512, 4096] {
            for d in [0, 8, 64] {
                let c = inst.iteration_cost(&BatchShape {
                    prefill_tokens: p,
                    prefill_ctx: 0,
                    decode_reqs: d,
                    decode_ctx: 256,
                });
                assert!(c.mfu > 0.0 && c.mfu < 0.7, "mfu={}", c.mfu);
            }
        }
    }
}
