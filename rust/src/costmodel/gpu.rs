//! GPU hardware specifications for the analytical cost model.

/// Hardware parameters of one accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: String,
    /// Peak dense bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM capacity, bytes.
    pub hbm_capacity: f64,
    /// Fixed per-iteration launch/runtime overhead, seconds.
    pub kernel_overhead: f64,
    /// Per-allreduce latency inside a TP group (NVLink), seconds.
    pub allreduce_latency: f64,
    /// Cross-instance interconnect bandwidth for KV transfer, bytes/s
    /// (paper testbed: 4×200 Gb/s ConnectX-6 RoCE per server).
    pub interconnect_bw: f64,
    /// Interconnect per-message latency, seconds.
    pub interconnect_latency: f64,
    /// HBM reserved for activations/workspace, bytes.
    pub activation_reserve: f64,
    /// Peak fraction reachable by large GEMMs (MFU ceiling).
    pub eff_max: f64,
    /// Token count at which the compute-efficiency ramp reaches half of
    /// eff_max (small batches underfill the SMs).
    pub eff_half_sat: f64,
}

impl GpuSpec {
    /// NVIDIA A100-SXM 80GB — the paper's testbed GPU.
    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "A100-80GB".to_string(),
            peak_flops: 312e12,
            hbm_bw: 2.0e12,
            hbm_capacity: 80e9,
            kernel_overhead: 4e-3, // vLLM python/runtime per-step overhead
            allreduce_latency: 18e-6,
            interconnect_bw: 25e9, // 200 Gb/s RoCE per NIC
            interconnect_latency: 8e-6,
            activation_reserve: 4e9,
            eff_max: 0.62,
            eff_half_sat: 32.0,
        }
    }

    /// The CPU PJRT "device" the live path runs on; calibrated at startup
    /// from measured step latencies, these defaults are only a seed.
    pub fn cpu_pjrt() -> GpuSpec {
        GpuSpec {
            name: "cpu-pjrt".to_string(),
            peak_flops: 5e10,
            hbm_bw: 2.0e10,
            hbm_capacity: 8e9,
            kernel_overhead: 1e-4,
            allreduce_latency: 0.0,
            interconnect_bw: 4e9,
            interconnect_latency: 2e-6,
            activation_reserve: 1e8,
            eff_max: 0.5,
            eff_half_sat: 32.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_matches_datasheet() {
        let g = GpuSpec::a100();
        assert_eq!(g.peak_flops, 312e12);
        assert_eq!(g.hbm_capacity, 80e9);
        assert!(g.interconnect_bw > 1e9);
    }
}
