//! End-to-end simulation benches — one per comparison row: how much wall
//! time one simulated serving second costs for each policy (these power
//! every table/figure harness, so their speed bounds experiment turnaround).
use dynaserve::costmodel::LlmSpec;
use dynaserve::experiments::runners::{run_once, System};
use dynaserve::metrics::SloConfig;
use dynaserve::util::benchkit::{bench, black_box};
use dynaserve::workload::TraceKind;

fn main() {
    let llm = LlmSpec::qwen25_14b();
    let slo = SloConfig::default();
    for sys in [System::Coloc { chunk: 1024 }, System::Disagg, System::DynaServe] {
        bench(&format!("sim: 30s BurstGPT @4qps [{}]", sys.name()), 4.0, || {
            black_box(run_once(sys, &llm, TraceKind::BurstGpt, 4.0, 30.0, 7, slo).0);
        });
    }
    bench("sim: 30s MiniReasoning @2qps [DynaServe]", 4.0, || {
        black_box(run_once(System::DynaServe, &llm, TraceKind::MiniReasoning, 2.0, 30.0, 7, slo).0);
    });
}
