//! End-to-end simulation benches — one per comparison row: how much wall
//! time one simulated serving second costs for each policy (these power
//! every table/figure harness, so their speed bounds experiment turnaround).
//!
//! `DYNASERVE_BENCH_JSON=path` additionally writes the rows as JSON —
//! `make artifacts` uses this to emit `BENCH_sim.json` so the perf
//! trajectory is tracked per PR (EXPERIMENTS.md §Perf).
use dynaserve::costmodel::LlmSpec;
use dynaserve::experiments::runners::{run_once, System};
use dynaserve::metrics::SloConfig;
use dynaserve::util::benchkit::{bench, black_box, write_json_report};
use dynaserve::workload::TraceKind;

fn main() {
    let llm = LlmSpec::qwen25_14b();
    let slo = SloConfig::default();
    let mut results = Vec::new();
    for sys in [System::Coloc { chunk: 1024 }, System::Disagg, System::DynaServe] {
        results.push(bench(&format!("sim: 30s BurstGPT @4qps [{}]", sys.name()), 4.0, || {
            black_box(run_once(sys, &llm, TraceKind::BurstGpt, 4.0, 30.0, 7, slo).0);
        }));
    }
    results.push(bench("sim: 30s MiniReasoning @2qps [DynaServe]", 4.0, || {
        black_box(run_once(System::DynaServe, &llm, TraceKind::MiniReasoning, 2.0, 30.0, 7, slo).0);
    }));
    write_json_report(&results);
}
