//! KV substrate benches: chunked-transfer timeline computation (driver hot
//! path) and block allocator churn.
use dynaserve::kv::{chunked_timeline, monolithic_timeline, BlockAllocator, LinkSpec};
use dynaserve::util::benchkit::{bench, black_box};

fn main() {
    let link = LinkSpec::default();
    let ready: Vec<(f64, f64)> = (0..64).map(|i| (i as f64 * 0.01, 512.0 * 196_608.0)).collect();
    bench("kv: chunked timeline (64 chunks)", 2.0, || {
        black_box(chunked_timeline(&ready, &link));
    });
    bench("kv: monolithic timeline (64 chunks)", 2.0, || {
        black_box(monolithic_timeline(&ready, &link));
    });

    bench("kv: allocator grow/release cycle (64 reqs)", 2.0, || {
        let mut a = BlockAllocator::new(8192, 16);
        for id in 0..64u64 {
            a.grow(id, 2048).unwrap();
        }
        for id in 0..64u64 {
            a.release(id);
        }
        black_box(a.free_blocks());
    });
}
