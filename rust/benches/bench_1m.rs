//! Memory-scale bench: one million requests through the virtual executor
//! (`make bench-1m`), sketch+streamed vs exact+materialized — the PR-6
//! bounded-memory claim measured, not asserted (EXPERIMENTS.md §Perf).
//!
//! Each variant runs ONCE (this is a minutes-long end-to-end run, not a
//! microbench) and records wall-clock plus the process peak RSS (`VmHWM`
//! from /proc/self/status). VmHWM is monotonic over the process lifetime,
//! so the bounded-memory sketch+streamed variant runs FIRST — its peak is
//! uncontaminated; the exact+materialized peak then subsumes it, which is
//! the right direction for the before/after comparison (the "after" row
//! must not be able to hide behind the "before" row's allocations).
//!
//! Environment knobs:
//! * `DYNASERVE_BENCH_1M_REQUESTS` — target request count (default
//!   1_000_000; CI's bench-smoke sets a small value so the harness is
//!   exercised without the full run).
//! * `DYNASERVE_BENCH_1M_EXACT=0` — skip the exact+materialized variant
//!   (e.g. on memory-constrained hosts; the sketch row still lands).
//! * `DYNASERVE_BENCH_JSON` — append rows to this report file (merged
//!   with any existing rows, e.g. bench_sim's, rather than overwritten).

use std::time::Instant;

use dynaserve::coordinator::predictor::PredictorConfig;
use dynaserve::coordinator::GlobalConfig;
use dynaserve::core::SloTarget;
use dynaserve::costmodel::{GpuSpec, InstanceSpec, LlmSpec};
use dynaserve::exec::policy::DynaServePolicy;
use dynaserve::exec::{ExecConfig, VirtualExecutor};
use dynaserve::metrics::{SloConfig, Summary};
use dynaserve::util::json::{obj, Json};
use dynaserve::workload::{ArrivalShape, LengthModel, Scenario, TrafficClass};

const SEED: u64 = 42;
const QPS: f64 = 50.0;
const FLEET: usize = 4;

/// A light single-class diurnal scenario sized to `n` expected requests:
/// short prompts/decodes keep the fleet ahead of the offered load, so the
/// in-flight set — and with it the streamed variant's peak memory — stays
/// O(fleet), independent of `n`.
fn diurnal(n: usize) -> Scenario {
    let duration = (n as f64 / QPS).max(60.0);
    Scenario {
        name: "bench-1m-diurnal",
        description: "light diurnal stream for the memory-scale bench",
        shape: ArrivalShape::Diurnal {
            base_qps: QPS,
            amplitude: 0.5,
            period: duration / 4.0,
        },
        classes: vec![TrafficClass {
            name: "light-chat",
            weight: 1.0,
            lengths: LengthModel::fit(48.0, 64.0, (8, 256), 12.0, 16.0, (2, 64)),
            slo: SloTarget { tbt: 0.100, ttft: Some(1.0) },
            multi_turn: None,
        }],
        duration,
        scale_events: vec![],
        faults: vec![],
    }
}

fn executor(sc: &Scenario, exact: bool) -> VirtualExecutor {
    let llm = LlmSpec::qwen25_14b();
    let spec = InstanceSpec::new(GpuSpec::a100(), llm.clone(), 1);
    let cfg = ExecConfig::builder(spec, FLEET)
        .slo(SloConfig::default())
        .horizon(2.0 * sc.duration)
        .exact_metrics(exact)
        .build()
        .expect("static bench config is valid");
    let gcfg = GlobalConfig {
        kv_bytes_per_token: llm.kv_bytes_per_token(),
        predictor: PredictorConfig { slo: SloConfig::default().tbt, ..Default::default() },
        ..Default::default()
    };
    VirtualExecutor::new(cfg, Box::new(DynaServePolicy::new(gcfg)))
}

/// Peak resident set (`VmHWM`) in kB — Linux only, `None` elsewhere.
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

struct Row {
    name: &'static str,
    wall_s: f64,
    peak_rss_kb: Option<u64>,
    summary: Summary,
}

fn report(n: usize, rows: &[Row]) {
    println!("\nbench-1m: {n} target requests, {QPS} qps diurnal, fleet of {FLEET}");
    for r in rows {
        let rss = r
            .peak_rss_kb
            .map(|kb| format!("{:.0} MB", kb as f64 / 1024.0))
            .unwrap_or_else(|| "n/a".to_string());
        println!(
            "  {:<24} wall {:>8.2} s   peak RSS {:>10}   completed {:>8}   tokens {:>10}",
            r.name, r.wall_s, rss, r.summary.completed, r.summary.total_tokens
        );
    }

    // merge-append into $DYNASERVE_BENCH_JSON so these rows coexist with
    // bench_sim's in the same BENCH_sim.json artifact
    let Ok(path) = std::env::var("DYNASERVE_BENCH_JSON") else { return };
    let mut arr = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| Json::parse(&s).ok())
        .and_then(|j| j.as_arr().map(|a| a.to_vec()))
        .unwrap_or_default();
    // replace any rows from a previous bench-1m run instead of stacking
    arr.retain(|j| {
        j.get("name")
            .and_then(|n| n.as_str())
            .map(|n| !n.starts_with("bench-1m"))
            .unwrap_or(true)
    });
    for r in rows {
        arr.push(obj([
            ("name", Json::from(r.name)),
            ("requests", Json::from(n)),
            ("wall_s", Json::from(r.wall_s)),
            (
                "peak_rss_mb",
                r.peak_rss_kb
                    .map(|kb| Json::from(kb as f64 / 1024.0))
                    .unwrap_or(Json::Null),
            ),
            ("completed", Json::from(r.summary.completed)),
            ("total_tokens", Json::from(r.summary.total_tokens)),
            ("good_tokens", Json::from(r.summary.good_tokens)),
        ]));
    }
    if let Some(dir) = std::path::Path::new(&path).parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    match std::fs::write(&path, Json::Arr(arr).dump_pretty()) {
        Ok(()) => println!("[bench json -> {path}]"),
        Err(e) => eprintln!("warn: could not write {path}: {e}"),
    }
}

fn main() {
    let n: usize = std::env::var("DYNASERVE_BENCH_1M_REQUESTS")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1_000_000);
    let run_exact = std::env::var("DYNASERVE_BENCH_1M_EXACT").as_deref() != Ok("0");
    let sc = diurnal(n);
    let mut rows = Vec::new();

    // "after": sketch metrics, streamed arrivals — bounded memory
    let mut ex = executor(&sc, false);
    let t0 = Instant::now();
    let streamed = ex.run_stream(sc.stream(SEED));
    rows.push(Row {
        name: "bench-1m sketch+stream",
        wall_s: t0.elapsed().as_secs_f64(),
        peak_rss_kb: peak_rss_kb(),
        summary: streamed,
    });
    drop(ex);

    // "before": exact metrics, materialized trace — O(n) memory
    if run_exact {
        let mut ex = executor(&sc, true);
        let t0 = Instant::now();
        let requests = sc.generate(SEED);
        let exact = ex.run(requests);
        rows.push(Row {
            name: "bench-1m exact+materialized",
            wall_s: t0.elapsed().as_secs_f64(),
            peak_rss_kb: peak_rss_kb(),
            summary: exact,
        });
        // counters are exact in both collector modes and the streamed
        // path is pinned bit-identical to the materialized one, so any
        // divergence here is a real lifecycle bug
        assert_eq!(rows[0].summary.completed, exact.completed);
        assert_eq!(rows[0].summary.total_tokens, exact.total_tokens);
        assert_eq!(rows[0].summary.good_tokens, exact.good_tokens);
    }

    report(n, &rows);
}
