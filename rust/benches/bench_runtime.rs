//! Live-path benches: PJRT step latency per bucket (the L1/L2 hot path as
//! seen from Rust) plus KV pack/transfer-extract host costs. Requires
//! `make artifacts`; skips gracefully when absent.
use dynaserve::runtime::Engine;
use dynaserve::util::benchkit::{bench, black_box};

fn main() {
    // Bench binaries run with CWD = rust/, but `make artifacts` writes to
    // the repository root — with no explicit dir argument, accept both.
    let loaded = match std::env::args().skip(1).find(|a| !a.starts_with('-')) {
        Some(dir) => Engine::load(&dir),
        None => Engine::load("artifacts")
            .or_else(|_| Engine::load(concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts"))),
    };
    let engine = match loaded {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping runtime benches (artifacts not built?): {e:#}");
            return;
        }
    };
    for b in engine.buckets().to_vec() {
        let mut seqs: Vec<_> = (0..b.batch).map(|_| engine.new_kv(b.capacity)).collect();
        let chunk: Vec<i32> = (1..=b.chunk as i32).collect();
        bench(&format!("pjrt step {}", b.name), 2.0, || {
            for s in seqs.iter_mut() {
                s.len = b.capacity / 2;
            }
            let mut refs: Vec<&mut _> = seqs.iter_mut().collect();
            let chunks: Vec<&[i32]> = (0..b.batch).map(|_| chunk.as_slice()).collect();
            black_box(engine.step(&b, &mut refs, &chunks).unwrap());
        });
    }
}
