//! Scheduler hot-path benches (Table 3's property: scheduling must be
//! negligible vs request latency). Covers Algorithm 1 (global split
//! search), Algorithm 2 (local batch composition) and the execution
//! predictor probe.
use dynaserve::coordinator::local::{DecodeEntry, PrefillEntry};
use dynaserve::coordinator::predictor::{completion_time, completion_time_digest, PredictorConfig};
use dynaserve::coordinator::{
    GlobalConfig, GlobalScheduler, InstanceSnapshot, LoadDigest, LocalConfig, LocalScheduler,
    ProfileTable, WorkItem,
};
use dynaserve::core::{InstanceId, Request};
use dynaserve::costmodel::{GpuSpec, InstanceSpec, LlmSpec};
use dynaserve::util::benchkit::{bench, black_box};

fn main() {
    let spec = InstanceSpec::new(GpuSpec::a100(), LlmSpec::qwen25_14b(), 1);
    let profile = ProfileTable::seeded(&spec);

    // loaded snapshots: 64 resident micro-requests per instance
    let work: Vec<WorkItem> = (0..64)
        .map(|i| WorkItem {
            prefill_remaining: (i * 131) % 4096,
            context: (i * 67) % 2048,
            decode_remaining: (i * 17) % 800,
        })
        .collect();
    let snaps: Vec<InstanceSnapshot> = (0..2)
        .map(|id| InstanceSnapshot {
            id: InstanceId(id),
            work: work.clone(),
            kv_utilization: 0.4,
            ..Default::default()
        })
        .collect();
    let loads: Vec<LoadDigest> = snaps.iter().map(LoadDigest::from_snapshot).collect();

    let mut global = GlobalScheduler::new(GlobalConfig::default());
    let req = Request::new(1, 0.0, 2048, 512);
    bench("global: Algorithm 1 split (digest path, loaded)", 2.0, || {
        black_box(global.schedule(&req, &loads, &profile));
    });
    bench("global: Algorithm 1 split (exact snapshots)", 2.0, || {
        black_box(global.schedule_exact(&req, &snaps, &profile));
    });

    let pcfg = PredictorConfig::default();
    bench("predictor: completion-time probe (64 items)", 2.0, || {
        black_box(completion_time(&work, &profile, &pcfg));
    });
    bench("predictor: digest probe (64-item digest)", 2.0, || {
        black_box(completion_time_digest(&loads[0], None, &profile, &pcfg));
    });

    let mut local = LocalScheduler::new(LocalConfig::default(), profile.clone());
    let decodes: Vec<DecodeEntry> =
        (0..48).map(|i| DecodeEntry { key: i, context: 512 + (i as usize * 13) % 1024 }).collect();
    let prefills: Vec<PrefillEntry> = (0..16)
        .map(|i| PrefillEntry { key: 100 + i, remaining: 1024, context: 0 })
        .collect();
    bench("local: Algorithm 2 batch composition (48d+16p)", 2.0, || {
        black_box(local.next_batch(&decodes, &prefills));
    });

    bench("profile: max_prefill_tokens inversion", 2.0, || {
        black_box(profile.max_prefill_tokens(0.1, 512, 16));
    });
}
