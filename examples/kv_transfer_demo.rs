//! Chunked KV transfer demo (§4.3): shows how shipping immutable KV chunks
//! as they are produced overlaps communication with computation, across a
//! sweep of link bandwidths and chunk sizes — both with the analytic
//! timelines (what the simulator uses) and through the live paced engine.

use std::sync::mpsc;
use std::time::Instant;

use dynaserve::kv::{chunked_timeline, monolithic_timeline, LinkSpec, TransferEngine, TransferJob};

fn main() {
    println!("== chunk-based KV transfer: exposed (non-overlapped) time ==\n");
    // a 4096-token prefill produced in 512-token chunks every 45 ms
    // (Qwen-14B on A100; 196 608 B of KV per token)
    let kv_per_token = 196_608.0;
    println!(
        "{:<22} {:>14} {:>14} {:>10}",
        "link", "at-handoff", "chunked", "reduction"
    );
    for (name, bw) in [("25 GB/s (RoCE)", 25e9), ("60 GB/s (4xNIC)", 60e9), ("300 GB/s (NVLink)", 300e9)]
    {
        let link = LinkSpec { bandwidth: bw, latency: 8e-6 };
        let ready: Vec<(f64, f64)> = (1..=8)
            .map(|i| (0.045 * i as f64, 512.0 * kv_per_token))
            .collect();
        let c = chunked_timeline(&ready, &link);
        let m = monolithic_timeline(&ready, &link);
        println!(
            "{:<22} {:>11.1} ms {:>11.1} ms {:>9.1}%",
            name,
            m.exposed * 1e3,
            c.exposed * 1e3,
            (1.0 - c.exposed / m.exposed) * 100.0
        );
    }

    println!("\n== live paced engine (real payloads through the kv-transfer thread) ==\n");
    let engine = TransferEngine::new(LinkSpec { bandwidth: 1e9, latency: 0.0 });
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    let chunks = 8;
    let chunk_floats = 1 << 18; // 1 MB per chunk
    for i in 0..chunks {
        engine.push(
            TransferJob {
                request: 1,
                token_range: (i * 64, (i + 1) * 64),
                payload: vec![1.0; chunk_floats],
                last: i == chunks - 1,
            },
            tx.clone(),
        );
    }
    let mut arrived = 0;
    while arrived < chunks {
        let job = rx.recv().unwrap();
        arrived += 1;
        println!(
            "chunk {:?} arrived at {:>6.1} ms{}",
            job.token_range,
            t0.elapsed().as_secs_f64() * 1e3,
            if job.last { "  (last → β activates)" } else { "" }
        );
    }
    let stats = engine.stats();
    println!(
        "\nmoved {:.1} MB in {} chunks — β started decoding one link-chunk after α finished.",
        stats.bytes.load(std::sync::atomic::Ordering::Relaxed) as f64 / 1e6,
        stats.chunks.load(std::sync::atomic::Ordering::Relaxed),
    );
}
