//! Quickstart — the end-to-end driver (DESIGN.md "End-to-end validation").
//!
//! Loads the real AOT-compiled TinyQwen model (Pallas attention → JAX step
//! function → HLO text → PJRT CPU), brings up two unified instances, and
//! serves a batched request stream through the full DynaServe stack:
//! global split scheduling (Algorithm 1), SLO-aware local batching
//! (Algorithm 2), and chunked KV transfer between instances (§4.3) — then
//! reports latency and throughput.
//!
//! Run:  make artifacts && cargo run --release --example quickstart

use dynaserve::metrics::SloConfig;
use dynaserve::server::{serve, ServeConfig};
use dynaserve::workload::TraceKind;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    println!("== DynaServe quickstart: live serving through PJRT ==\n");
    println!("loading artifacts from `{artifacts}` (run `make artifacts` if missing)…");

    let report = serve(ServeConfig {
        artifacts,
        n_instances: 2,
        requests: 32,
        qps: 4.0,
        workload: TraceKind::BurstGpt, // shapes scaled to the tiny context
        seed: 42,
        slo: SloConfig { tbt: 0.250, ttft: None },
        autoscale: None, // fixed two-instance fleet for the quickstart
    })?;

    report.print();

    // e2e sanity: every request completed and produced real tokens
    assert_eq!(report.summary.completed, 32, "all requests must complete");
    assert!(report.summary.total_tokens > 100, "tokens were generated");
    println!("\nquickstart OK — all layers compose (Pallas → JAX → HLO → PJRT → Rust).");
    Ok(())
}
