//! Trace serving — compare the three architectures on a real-world-shaped
//! workload at A100 scale (simulated substrate, same scheduler code as the
//! live path).
//!
//! Run:  cargo run --release --example trace_serving -- [workload] [qps]
//!       workloads: burstgpt | azure-code | arxiv-summ | mini-reasoning | hybrid

use dynaserve::costmodel::LlmSpec;
use dynaserve::experiments::runners::{coloc_chunk_for, run_once, System};
use dynaserve::metrics::SloConfig;
use dynaserve::workload::TraceKind;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let kind = TraceKind::by_name(args.get(1).map(|s| s.as_str()).unwrap_or("burstgpt"))
        .ok_or_else(|| anyhow::anyhow!("unknown workload"))?;
    let qps: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4.0);
    let llm = LlmSpec::qwen25_14b();
    let slo = SloConfig::default();

    println!("== {} @ {qps} QPS, Qwen-14B on 2x A100, 100 ms TBT SLO ==\n", kind.name());
    println!(
        "{:<12} {:>10} {:>10} {:>8} {:>10} {:>10} {:>9}",
        "system", "goodput", "tok/s", "rps", "p50 TBT", "p99 TBT", "attain%"
    );
    for sys in [System::Coloc { chunk: coloc_chunk_for(kind) }, System::Disagg, System::DynaServe] {
        let (s, sim) = run_once(sys, &llm, kind, qps, 60.0, 42, slo);
        println!(
            "{:<12} {:>10.0} {:>10.0} {:>8.2} {:>8.1}ms {:>8.1}ms {:>9.1}",
            sys.name(),
            s.goodput_tok_s,
            s.throughput_tok_s,
            s.rps,
            s.p50_tbt * 1e3,
            s.p99_tbt * 1e3,
            s.attainment * 100.0,
        );
        for inst in sim.instances() {
            println!(
                "             └ instance {}: MFU {:.1}%  HBM {:.1}%",
                inst.id,
                inst.mfu() * 100.0,
                inst.hbm_usage() * 100.0
            );
        }
    }
    Ok(())
}
