//! Capacity planner — answers the deployment question "how many QPS of my
//! workload mix can this cluster sustain under a P99 TBT SLO?" for each
//! architecture, plus the GPU savings DynaServe's elasticity buys.
//!
//! Run:  cargo run --release --example capacity_planner -- [workload] [slo_ms]

use dynaserve::costmodel::LlmSpec;
use dynaserve::experiments::runners::{coloc_chunk_for, run_once, System};
use dynaserve::metrics::{capacity_search, SloConfig};
use dynaserve::workload::TraceKind;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let kind = TraceKind::by_name(args.get(1).map(|s| s.as_str()).unwrap_or("hybrid"))
        .ok_or_else(|| anyhow::anyhow!("unknown workload"))?;
    let slo_ms: f64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(100.0);
    let slo = SloConfig { tbt: slo_ms / 1e3, ttft: None };
    let llm = LlmSpec::qwen25_14b();

    println!(
        "== capacity planning: {} under {slo_ms:.0} ms p99-TBT, Qwen-14B, 2x A100 ==\n",
        kind.name()
    );
    let mut caps = Vec::new();
    for sys in [System::Coloc { chunk: coloc_chunk_for(kind) }, System::Disagg, System::DynaServe] {
        let (cap, at) = capacity_search(&slo, 60.0, 0.25, 2.0, 0.15, |q| {
            run_once(sys, &llm, kind, q, 60.0, 42, slo).0
        });
        println!(
            "{:<12} capacity {:>5.2} rps   goodput at capacity {:>7.0} tok/s   p99 {:>5.1} ms",
            sys.name(),
            cap,
            at.goodput_tok_s,
            at.p99_tbt * 1e3
        );
        caps.push((sys.name(), cap));
    }
    let dynaserve = caps.iter().find(|c| c.0 == "DynaServe").unwrap().1;
    println!();
    for (name, cap) in &caps {
        if *name != "DynaServe" && *cap > 0.0 {
            let ratio = dynaserve / cap;
            println!(
                "vs {name}: {ratio:.2}x capacity — serving the same load needs ~{:.0}% of the GPUs",
                100.0 / ratio
            );
        }
    }
    Ok(())
}
