"""L2 correctness: TinyQwen step function — shapes, KV-cache semantics,
incremental (prefill-then-decode) equivalence, and Pallas-vs-ref parity at
the model level.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig()
PARAMS = M.init_params(CFG)


def run_step(kv_k, kv_v, tokens, pos, impl="ref"):
    return M.step(CFG, PARAMS, kv_k, kv_v, tokens, pos, attn_impl=impl)


def test_param_count_matches_specs():
    total = 0
    for _, shape in M.param_specs(CFG):
        n = 1
        for s in shape:
            n *= s
        total += n
    assert total == M.param_count(CFG)
    assert 1_000_000 < total < 1_100_000  # ~1M params, per DESIGN.md


def test_step_shapes():
    b, c, s = 2, 8, 64
    kv_k, kv_v = M.empty_cache(CFG, b, s)
    tokens = jnp.zeros((b, c), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    logits, nk, nv = run_step(kv_k, kv_v, tokens, pos)
    assert logits.shape == (b, CFG.vocab)
    assert nk.shape == (CFG.n_layers, b, CFG.n_kv_heads, s, CFG.head_dim)
    assert nv.shape == nk.shape


@pytest.mark.parametrize("impl", ["pallas_flash", "pallas_simple"])
def test_pallas_model_matches_ref_model(impl):
    b, c, s = 2, 16, 64
    kv_k, kv_v = M.empty_cache(CFG, b, s)
    tokens = jax.random.randint(jax.random.PRNGKey(0), (b, c), 0, CFG.vocab)
    pos = jnp.zeros((b,), jnp.int32)
    lr, kr, vr = run_step(kv_k, kv_v, tokens, pos, "ref")
    lp, kp, vp = run_step(kv_k, kv_v, tokens, pos, impl)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(lp), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(kr), np.asarray(kp), atol=1e-5, rtol=1e-5)


def test_incremental_equals_full_prefill():
    """prefill(N) then decode(1) must equal prefill(N+1): the correctness
    contract the whole serving stack rests on."""
    b, s = 2, 64
    kv_k, kv_v = M.empty_cache(CFG, b, s)
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, 9), 0, CFG.vocab)
    full, _, _ = run_step(kv_k, kv_v, toks, jnp.zeros((b,), jnp.int32))
    l8, k8, v8 = run_step(kv_k, kv_v, toks[:, :8], jnp.zeros((b,), jnp.int32))
    inc, _, _ = run_step(k8, v8, toks[:, 8:9], jnp.full((b,), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(full), np.asarray(inc), atol=2e-5, rtol=2e-5)


def test_chunked_prefill_equals_monolithic():
    """Splitting a prompt into chunks (the micro-request execution model)
    must be numerically identical to one-shot prefill."""
    b, s = 1, 128
    kv_k, kv_v = M.empty_cache(CFG, b, s)
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, 48), 0, CFG.vocab)
    mono, mk, mv = run_step(kv_k, kv_v, toks, jnp.zeros((b,), jnp.int32))
    # three chunks of 16
    k, v = kv_k, kv_v
    for i in range(3):
        lg, k, v = run_step(k, v, toks[:, 16 * i : 16 * (i + 1)],
                            jnp.full((b,), 16 * i, jnp.int32))
    np.testing.assert_allclose(np.asarray(mono), np.asarray(lg), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(k), atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(v), atol=2e-5, rtol=2e-5)


def test_scatter_chunk_preserves_other_slots():
    """Cache write touches exactly [pos, pos+C) per sequence."""
    cache = jnp.arange(2 * 2 * 16 * 4, dtype=jnp.float32).reshape(2, 2, 16, 4)
    new = -jnp.ones((2, 2, 3, 4), jnp.float32)
    pos = jnp.array([2, 9], jnp.int32)
    out = M._scatter_chunk(cache, new, pos)
    out = np.asarray(out)
    ref = np.asarray(cache).copy()
    ref[0, :, 2:5] = -1
    ref[1, :, 9:12] = -1
    np.testing.assert_array_equal(out, ref)


@settings(max_examples=10, deadline=None)
@given(
    c=st.sampled_from([1, 2, 4, 8]),
    posbase=st.integers(0, 40),
    seed=st.integers(0, 1000),
)
def test_scatter_roundtrip_hypothesis(c, posbase, seed):
    b, hkv, s, d = 2, 2, 64, 8
    key = jax.random.PRNGKey(seed)
    cache = jax.random.normal(key, (b, hkv, s, d))
    new = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, hkv, c, d))
    pos = jnp.array([posbase, min(posbase + 5, s - c)], jnp.int32)
    out = np.asarray(M._scatter_chunk(cache, new, pos))
    for bi in range(b):
        p = int(pos[bi])
        np.testing.assert_allclose(out[bi, :, p : p + c], np.asarray(new)[bi], atol=1e-6)
        mask = np.ones(s, bool)
        mask[p : p + c] = False
        np.testing.assert_allclose(
            out[bi][:, mask], np.asarray(cache)[bi][:, mask], atol=1e-6
        )


def test_decode_distinct_positions_per_sequence():
    """Batched decode with different cache lengths per sequence."""
    b, s = 4, 64
    kv_k, kv_v = M.empty_cache(CFG, b, s)
    # seed each sequence with a different-length prefix, one at a time
    prefix_lens = [3, 10, 17, 31]
    k, v = kv_k, kv_v
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, 31), 0, CFG.vocab)
    # prefill each sequence's prefix via per-sequence masked writes:
    for i, n in enumerate(prefix_lens):
        _, kk, vv = M.step(
            CFG, PARAMS,
            k[:, i : i + 1], v[:, i : i + 1],
            toks[i : i + 1, :n], jnp.zeros((1,), jnp.int32),
            attn_impl="ref",
        )
        k = k.at[:, i : i + 1].set(kk)
        v = v.at[:, i : i + 1].set(vv)
    # batched decode with heterogeneous pos
    dec = jax.random.randint(jax.random.PRNGKey(4), (b, 1), 0, CFG.vocab)
    pos = jnp.array(prefix_lens, jnp.int32)
    batched, _, _ = M.step(CFG, PARAMS, k, v, dec, pos, attn_impl="ref")
    # vs one-at-a-time
    for i, n in enumerate(prefix_lens):
        single, _, _ = M.step(
            CFG, PARAMS,
            k[:, i : i + 1], v[:, i : i + 1],
            dec[i : i + 1], jnp.array([n], jnp.int32),
            attn_impl="ref",
        )
        np.testing.assert_allclose(
            np.asarray(batched[i]), np.asarray(single[0]), atol=2e-5, rtol=2e-5
        )


def test_step_fn_flat_signature():
    fn = M.make_step_fn(CFG, attn_impl="ref")
    b, c, s = 1, 4, 32
    kv_k, kv_v = M.empty_cache(CFG, b, s)
    tokens = jnp.zeros((b, c), jnp.int32)
    pos = jnp.zeros((b,), jnp.int32)
    last = jnp.full((b,), c - 1, jnp.int32)
    out = fn(*PARAMS, kv_k, kv_v, tokens, pos, last)
    assert len(out) == 3
    assert out[0].shape == (b, CFG.vocab)
