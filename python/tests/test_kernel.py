"""L1 correctness: Pallas attention kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; fixed cases pin down the regressions we
care most about (decode step C=1, chunk boundaries, fresh cache pos=0,
full cache pos=S-C).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def make_inputs(b, hq, hkv, c, s, d, dtype, seed=0):
    k0, k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(k0, (b, hq, c, d), dtype)
    k = jax.random.normal(k1, (b, hkv, s, d), dtype)
    v = jax.random.normal(k2, (b, hkv, s, d), dtype)
    pos = jax.random.randint(k3, (b,), 0, s - c + 1, jnp.int32)
    return q, k, v, pos


def tol(dtype):
    if dtype == jnp.bfloat16:
        return dict(atol=2e-2, rtol=2e-2)
    return dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("variant", ["simple", "flash"])
@pytest.mark.parametrize(
    "b,hq,hkv,c,s,d",
    [
        (1, 4, 2, 1, 128, 32),   # decode step
        (8, 4, 2, 1, 128, 32),   # batched decode
        (1, 4, 2, 64, 128, 32),  # prefill chunk
        (2, 4, 4, 32, 256, 32),  # MHA (no GQA)
        (1, 8, 2, 16, 64, 16),   # wide GQA group
    ],
)
def test_kernel_matches_ref_fixed(variant, b, hq, hkv, c, s, d):
    q, k, v, pos = make_inputs(b, hq, hkv, c, s, d, jnp.float32)
    got = A.attention(q, k, v, pos, variant=variant)
    want = ref.ref_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(jnp.float32))


@pytest.mark.parametrize("variant", ["simple", "flash"])
def test_kernel_pos_zero_and_full(variant):
    """Boundary positions: empty cache and exactly-full cache."""
    for posval in (0, 128 - 16):
        q, k, v, _ = make_inputs(2, 4, 2, 16, 128, 32, jnp.float32, seed=7)
        pos = jnp.full((2,), posval, jnp.int32)
        got = A.attention(q, k, v, pos, variant=variant)
        want = ref.ref_attention(q, k, v, pos)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5
        )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("variant", ["simple", "flash"])
def test_kernel_dtypes(dtype, variant):
    q, k, v, pos = make_inputs(2, 4, 2, 8, 64, 32, dtype, seed=3)
    got = A.attention(q, k, v, pos, variant=variant)
    assert got.dtype == dtype
    want = ref.ref_attention(q, k, v, pos)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    hkv=st.sampled_from([1, 2, 4]),
    group=st.sampled_from([1, 2, 4]),
    logc=st.integers(0, 5),
    logs_extra=st.integers(0, 3),
    d=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_kernel_flash_hypothesis(b, hkv, group, logc, logs_extra, d, seed):
    """Random shape sweep: flash kernel == oracle for any C<=S config."""
    c = 2**logc
    s = max(c * (2**logs_extra), 8)
    q, k, v, pos = make_inputs(b, hkv * group, hkv, c, s, d, jnp.float32, seed)
    got = A.attention(q, k, v, pos, variant="flash")
    want = ref.ref_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


@settings(max_examples=15, deadline=None)
@given(
    c=st.sampled_from([1, 4, 16, 64]),
    s=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_variants_agree(c, s, seed):
    """Differential test: the two kernel implementations agree with each
    other (catches oracle-blind-spot bugs)."""
    q, k, v, pos = make_inputs(2, 4, 2, c, s, 32, jnp.float32, seed)
    a = A.attention(q, k, v, pos, variant="simple")
    b_ = A.attention(q, k, v, pos, variant="flash")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=3e-5, rtol=3e-5)


def test_block_picker():
    assert A._pick_block(64, 64) == 64
    assert A._pick_block(1, 64) == 1
    assert A._pick_block(96, 64) == 48
    assert A._pick_block(128, 64) == 64


def test_vmem_footprint_fits_tpu_budget():
    """The documented flash tiles must fit comfortably in a 16 MiB VMEM."""
    fp = A.vmem_footprint_bytes(block_q=64, block_kv=64, head_dim=32)
    assert fp < 1 << 20  # tiny model: well under 1 MiB per grid cell
    fp_big = A.vmem_footprint_bytes(block_q=128, block_kv=128, head_dim=128)
    assert fp_big < 16 << 20


def test_flash_rejects_bad_blocks():
    q, k, v, pos = make_inputs(1, 4, 2, 8, 64, 32, jnp.float32)
    with pytest.raises(AssertionError):
        A.attention(q, k, v, pos, variant="flash", block_q=3)


def test_unknown_variant():
    q, k, v, pos = make_inputs(1, 4, 2, 8, 64, 32, jnp.float32)
    with pytest.raises(ValueError):
        A.attention(q, k, v, pos, variant="nope")
