"""AOT pipeline tests: lowering produces loadable HLO text, the manifest ABI
is consistent, and params.bin round-trips.
"""

import json
import pathlib
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

CFG = M.ModelConfig()


def test_lower_bucket_produces_hlo_text():
    text = aot.lower_bucket(CFG, b=1, c=1, s=32, attn_impl="ref")
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # static shapes visible in the entry signature
    assert "f32[4,1,2,32,32]" in text  # kv cache [L,B,Hkv,S,D]
    assert "s32[1,1]" in text  # tokens


def test_lower_bucket_pallas_interpret_lowers_to_plain_hlo():
    """interpret=True pallas must not leave custom-calls the CPU PJRT
    client cannot execute."""
    text = aot.lower_bucket(CFG, b=1, c=1, s=32, attn_impl="pallas_flash")
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_params_bin_roundtrip(tmp_path):
    table = aot.write_params(CFG, tmp_path, seed=42)
    blob = (tmp_path / "params.bin").read_bytes()
    total = sum(e["len"] for e in table)
    assert len(blob) == total * 4
    assert total == M.param_count(CFG)
    # offsets are contiguous and ordered
    off = 0
    for e in table:
        assert e["offset"] == off
        off += e["len"] * 4
    # a tensor read back from the blob matches init_params
    params = M.init_params(CFG, 42)
    e = table[1]  # layer0.attn_norm
    arr = np.frombuffer(blob, np.float32, count=e["len"], offset=e["offset"])
    np.testing.assert_allclose(arr, np.asarray(params[1]).ravel(), atol=0)


def test_manifest_written_by_cli(tmp_path):
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
         "--buckets", "1x1x32", "--attn", "ref"],
        check=True,
        cwd=pathlib.Path(__file__).resolve().parents[1],
    )
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["model"]["param_count"] == M.param_count(CFG)
    assert len(man["buckets"]) == 1
    b = man["buckets"][0]
    assert (tmp_path / b["file"]).exists()
    assert b["batch"] == 1 and b["chunk"] == 1 and b["capacity"] == 32
    assert [p["name"] for p in man["params"]] == [n for n, _ in M.param_specs(CFG)]


def test_default_buckets_cover_decode_and_prefill():
    decode = [b for b in aot.DEFAULT_BUCKETS if b[1] == 1]
    prefill = [b for b in aot.DEFAULT_BUCKETS if b[1] > 1]
    assert decode and prefill
    # every prefill chunk size must fit its capacity
    for b, c, s in aot.DEFAULT_BUCKETS:
        assert c <= s
