# Make `from compile import ...` resolve when pytest runs from the repo root
# (the Makefile runs pytest from python/; this keeps both entrypoints green).
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
