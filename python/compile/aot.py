"""AOT pipeline: lower the TinyQwen step function to HLO text artifacts the
Rust runtime loads via PJRT.

Run once at build time (``make artifacts``); never on the request path.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under --out, default ../artifacts):
  step_b{B}_c{C}_s{S}.hlo.txt   one per (batch, chunk, capacity) bucket
  params.bin                    f32 little-endian tensors, param_specs order
  manifest.json                 model config, param table, bucket table
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model as M

# Bucket family: (batch, chunk, capacity). C==1 buckets serve decode
# iterations (batched across sequences); C>1 buckets serve prefill chunks.
# The Rust runtime rounds each iteration up to the nearest bucket.
DEFAULT_BUCKETS: list[tuple[int, int, int]] = [
    # decode steps
    (1, 1, 128), (4, 1, 128), (8, 1, 128),
    (1, 1, 256), (4, 1, 256), (8, 1, 256),
    # prefill chunks
    (1, 32, 128), (1, 64, 128),
    (1, 32, 256), (1, 64, 256), (1, 128, 256),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(
    cfg: M.ModelConfig, b: int, c: int, s: int, attn_impl: str
) -> str:
    fn = M.make_step_fn(cfg, attn_impl=attn_impl)
    dtype = jnp.dtype(cfg.dtype)
    param_shapes = [
        jax.ShapeDtypeStruct(shape, dtype) for _, shape in M.param_specs(cfg)
    ]
    kv_shape = jax.ShapeDtypeStruct(
        (cfg.n_layers, b, cfg.n_kv_heads, s, cfg.head_dim), dtype
    )
    tokens = jax.ShapeDtypeStruct((b, c), jnp.int32)
    pos = jax.ShapeDtypeStruct((b,), jnp.int32)
    last_idx = jax.ShapeDtypeStruct((b,), jnp.int32)
    lowered = jax.jit(fn).lower(*param_shapes, kv_shape, kv_shape, tokens, pos, last_idx)
    return to_hlo_text(lowered)


def write_params(cfg: M.ModelConfig, out_dir: pathlib.Path, seed: int) -> list[dict]:
    params = M.init_params(cfg, seed)
    table = []
    offset = 0
    blobs = []
    for (name, shape), p in zip(M.param_specs(cfg), params):
        arr = np.asarray(p, dtype=np.float32)
        blobs.append(arr.tobytes())
        table.append(
            {
                "name": name,
                "shape": list(shape),
                "offset": offset,
                "len": arr.size,
            }
        )
        offset += arr.size * 4
    (out_dir / "params.bin").write_bytes(b"".join(blobs))
    return table


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument(
        "--attn", default="pallas_flash",
        choices=["pallas_flash", "pallas_simple", "ref"],
    )
    ap.add_argument(
        "--buckets", default=None,
        help="comma list of BxCxS triples, e.g. 1x1x128,1x64x256",
    )
    args = ap.parse_args()

    cfg = M.ModelConfig()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    buckets = DEFAULT_BUCKETS
    if args.buckets:
        buckets = [
            tuple(int(x) for x in spec.split("x"))
            for spec in args.buckets.split(",")
        ]

    param_table = write_params(cfg, out_dir, args.seed)

    bucket_table = []
    for b, c, s in buckets:
        t0 = time.time()
        name = f"step_b{b}_c{c}_s{s}"
        text = lower_bucket(cfg, b, c, s, args.attn)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        bucket_table.append(
            {
                "name": name,
                "batch": b,
                "chunk": c,
                "capacity": s,
                "file": path.name,
                "sha256_16": digest,
            }
        )
        print(f"  {name}: {len(text)} chars in {time.time() - t0:.1f}s")

    manifest = {
        "model": {
            "family": "tinyqwen",
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_q_heads": cfg.n_q_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "ffn": cfg.ffn,
            "rope_theta": cfg.rope_theta,
            "dtype": cfg.dtype,
            "param_count": int(M.param_count(cfg)),
            "attn_impl": args.attn,
            "seed": args.seed,
        },
        "params_file": "params.bin",
        "params": param_table,
        "buckets": bucket_table,
        # input order of every step artifact:
        #   params (param_specs order), kv_k, kv_v, tokens, pos
        "input_order": ["params...", "kv_k", "kv_v", "tokens", "pos", "last_idx"],
        "output_order": ["logits", "new_kv_k", "new_kv_v"],
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(bucket_table)} buckets + params to {out_dir}")


if __name__ == "__main__":
    main()
