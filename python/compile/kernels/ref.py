"""Pure-jnp correctness oracles for the Pallas attention kernels.

These are the ground truth the pytest/hypothesis suites compare against.
Everything here is deliberately straight-line jnp — no pallas, no tricks —
so a mismatch always implicates the kernel, not the oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Large-negative instead of -inf: keeps bf16/f16 softmax free of NaNs on
# fully-masked tails while being indistinguishable after exp().
NEG_INF = -1e30


def expand_gqa(k: jax.Array, n_q_heads: int) -> jax.Array:
    """Expand [B, Hkv, S, D] KV heads to [B, Hq, S, D] by repetition."""
    b, hkv, s, d = k.shape
    assert n_q_heads % hkv == 0, "q heads must be a multiple of kv heads"
    group = n_q_heads // hkv
    return jnp.repeat(k, group, axis=1)


def ref_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    pos: jax.Array,
) -> jax.Array:
    """Causal chunk attention over a (padded) KV cache.

    Args:
      q:   [B, Hq, C, D] queries for the C new tokens of each sequence.
      k:   [B, Hkv, S, D] key cache, already containing the new tokens.
      v:   [B, Hkv, S, D] value cache, already containing the new tokens.
      pos: [B] int32, number of tokens resident in the cache *before* this
           chunk; query i of sequence b sits at global position pos[b] + i
           and may attend cache slots j <= pos[b] + i.

    Returns: [B, Hq, C, D] attention output in q's dtype.
    """
    b, hq, c, d = q.shape
    s = k.shape[2]
    k = expand_gqa(k, hq)
    v = expand_gqa(v, hq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    scores = (
        jnp.einsum("bhcd,bhsd->bhcs", q.astype(jnp.float32), k.astype(jnp.float32))
        * scale
    )
    col = jnp.arange(s)[None, None, None, :]
    row = pos[:, None, None, None] + jnp.arange(c)[None, None, :, None]
    scores = jnp.where(col <= row, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhcs,bhsd->bhcd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_angles(
    positions: jax.Array, head_dim: int, theta: float
) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding. positions [..., T] -> [..., T, D/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def ref_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.

    x: [B, T, H, D] (pairs split as [even-half | odd-half]), positions [B, T].
    """
    d = x.shape[-1]
    cos, sin = rope_angles(positions, d, theta)  # [B, T, D/2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
