"""Pallas attention kernels — the serving hot-spot (Layer 1).

Two variants of causal chunk attention over a padded KV cache:

* ``attention_simple`` — whole-context kernel, grid over (batch, q-head).
  The entire K/V cache row for the head lives in VMEM. Easiest to verify;
  used as a stepping stone and as a second implementation for differential
  testing against the flash variant.

* ``attention_flash`` — flash-attention-style kernel: grid over
  (batch, q-head, q-block); K/V consumed in ``block_kv``-sized tiles with an
  online-softmax accumulator (running max / running sum). This restates the
  paper's CUDA threadblock schedule in TPU terms: the query tile and the
  accumulator are VMEM-resident, KV streams through VMEM tile by tile, and
  matmuls accumulate in f32 (MXU-style ``preferred_element_type``).

Both are launched with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and interpret mode lowers to plain HLO that
round-trips through the Rust loader. See DESIGN.md §Hardware-Adaptation.

GQA is expressed in the BlockSpec index maps: q-head ``h`` reads kv-head
``h // (Hq // Hkv)`` — no materialized head expansion.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _simple_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, scale):
    """One (batch, q-head) cell: full-cache attention for a C-token chunk."""
    pos = pos_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)  # [C, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [S, D]
    v = v_ref[0, 0].astype(jnp.float32)  # [S, D]
    c, d = q.shape
    s = k.shape[0]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    col = jax.lax.broadcasted_iota(jnp.int32, (c, s), 1)
    row = jax.lax.broadcasted_iota(jnp.int32, (c, s), 0) + pos
    scores = jnp.where(col <= row, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


def _flash_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, scale, block_kv):
    """One (batch, q-head, q-block) cell: online-softmax over KV tiles.

    VMEM footprint per cell: q tile [BQ, D] + one KV tile pair
    [2, BKV, D] + accumulator [BQ, D] + stats [BQ, 2] — the flash
    HBM<->VMEM schedule. (In interpret mode the full K/V row is staged; on
    a real TPU the fori_loop tiles become the streamed dimension.)
    """
    pos = pos_ref[0]
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)  # [BQ, D]
    bq, d = q.shape
    s = k_ref.shape[2]
    n_kv = s // block_kv
    row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_kv), 0) + pos

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(
            k_ref, (0, 0, pl.dslice(i * block_kv, block_kv), slice(None))
        ).astype(jnp.float32)
        v = pl.load(
            v_ref, (0, 0, pl.dslice(i * block_kv, block_kv), slice(None))
        ).astype(jnp.float32)
        scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        col = i * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (bq, block_kv), 1
        )
        scores = jnp.where(col <= row, scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, n_kv, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / l).astype(o_ref.dtype)


def _pick_block(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (power-of-two friendly)."""
    b = min(n, preferred)
    while n % b != 0:
        b -= 1
    return b


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    pos: jax.Array,
    *,
    variant: str = "flash",
    block_q: int | None = None,
    block_kv: int | None = None,
    interpret: bool = True,
):
    """Causal chunk attention over a padded KV cache (Pallas).

    Args:
      q:   [B, Hq, C, D] queries for the chunk.
      k:   [B, Hkv, S, D] key cache (new tokens already written).
      v:   [B, Hkv, S, D] value cache.
      pos: [B] int32 cache length before the chunk.
      variant: "flash" (tiled online-softmax) or "simple" (whole-context).

    Returns: [B, Hq, C, D], same dtype as q. Matches ``ref.ref_attention``.
    """
    b, hq, c, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = 1.0 / math.sqrt(d)
    pos = pos.astype(jnp.int32)

    pos_spec = pl.BlockSpec((1,), lambda bi, hi, *rest: (bi,))
    kv_spec = lambda: pl.BlockSpec(
        (1, 1, s, d), lambda bi, hi, *rest: (bi, hi // group, 0, 0)
    )
    out_shape = jax.ShapeDtypeStruct((b, hq, c, d), q.dtype)

    if variant == "simple":
        grid = (b, hq)
        q_spec = pl.BlockSpec((1, 1, c, d), lambda bi, hi: (bi, hi, 0, 0))
        o_spec = pl.BlockSpec((1, 1, c, d), lambda bi, hi: (bi, hi, 0, 0))
        kernel = functools.partial(_simple_kernel, scale=scale)
    elif variant == "flash":
        bq = block_q or _pick_block(c, 64)
        bkv = block_kv or _pick_block(s, 64)
        assert c % bq == 0 and s % bkv == 0
        grid = (b, hq, c // bq)
        q_spec = pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi: (bi, hi, qi, 0))
        o_spec = pl.BlockSpec((1, 1, bq, d), lambda bi, hi, qi: (bi, hi, qi, 0))
        kernel = functools.partial(_flash_kernel, scale=scale, block_kv=bkv)
    else:
        raise ValueError(f"unknown attention variant: {variant!r}")

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pos_spec, q_spec, kv_spec(), kv_spec()],
        out_specs=o_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(pos, q, k, v)


def vmem_footprint_bytes(
    *, block_q: int, block_kv: int, head_dim: int, dtype_bytes: int = 4
) -> int:
    """Estimated per-grid-cell VMEM footprint of the flash kernel.

    q tile + one K tile + one V tile + f32 accumulator + running stats.
    Used by DESIGN.md §Perf / EXPERIMENTS.md §Perf for the TPU-side
    analysis (interpret mode gives no hardware signal).
    """
    q_tile = block_q * head_dim * dtype_bytes
    kv_tiles = 2 * block_kv * head_dim * dtype_bytes
    acc = block_q * head_dim * 4
    stats = block_q * 2 * 4
    return q_tile + kv_tiles + acc + stats
