"""Layer 2 — the JAX model: "TinyQwen", a GQA transformer with a unified
*step* function that is the compute form of DynaServe's micro-request
abstraction.

``step`` processes C new tokens per sequence against a KV cache of capacity
S. C>1 is a prefill chunk, C=1 is a decode step — so *any* contiguous token
span (a micro-request, whether pure prefill, pure decode, or a mix) executes
as a sequence of step calls. The Rust coordinator picks a bucketed
``step_b{B}_c{C}_s{S}`` artifact per iteration.

Architecture (Qwen-2.5-style, scaled to ~1M params for the CPU testbed):
byte-level vocab 256, d_model 128, 4 layers, 4 q-heads / 2 kv-heads
(GQA), head_dim 32, SwiGLU FFN 512, RMSNorm, RoPE.

Python here is build-time only: ``aot.py`` lowers ``step`` to HLO text and
the Rust runtime executes it via PJRT. Nothing in this file runs on the
request path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels import attention as pallas_attn
from compile.kernels import ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_q_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    ffn: int = 512
    rope_theta: float = 10000.0
    dtype: str = "float32"

    @property
    def q_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) list — the param ABI shared with the Rust
    runtime via manifest.json. Order here == positional input order of the
    lowered step function == layout order inside params.bin."""
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (cfg.vocab, cfg.d_model))
    ]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        specs += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.q_dim)),
            (p + "wk", (cfg.d_model, cfg.kv_dim)),
            (p + "wv", (cfg.d_model, cfg.kv_dim)),
            (p + "wo", (cfg.q_dim, cfg.d_model)),
            (p + "ffn_norm", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.ffn)),
            (p + "w_up", (cfg.d_model, cfg.ffn)),
            (p + "w_down", (cfg.ffn, cfg.d_model)),
        ]
    specs += [
        ("final_norm", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 42) -> list[jax.Array]:
    """Deterministic scaled-normal init (serving needs a real network, not a
    trained one — latency/throughput are weight-agnostic)."""
    key = jax.random.PRNGKey(seed)
    dtype = jnp.dtype(cfg.dtype)
    out = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            out.append(jnp.ones(shape, dtype))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            out.append((jax.random.normal(sub, shape, jnp.float32) * std).astype(dtype))
    return out


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_specs(cfg))


def _scatter_chunk(cache: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write a [B, Hkv, C, D] chunk into a [B, Hkv, S, D] cache at per-
    sequence offsets ``pos`` (one-hot formulation: branch-free, static
    shapes, lowers to plain HLO)."""
    b, hkv, s, d = cache.shape
    c = new.shape[2]
    idx = pos[:, None] + jnp.arange(c)[None, :]  # [B, C]
    onehot = jax.nn.one_hot(idx, s, dtype=cache.dtype)  # [B, C, S]
    keep = 1.0 - jnp.sum(onehot, axis=1)  # [B, S]
    written = jnp.einsum("bcs,bhcd->bhsd", onehot, new)
    return cache * keep[:, None, :, None] + written


def _attention_dispatch(impl: str) -> Callable:
    if impl == "ref":
        return ref.ref_attention
    if impl == "pallas_simple":
        return lambda q, k, v, pos: pallas_attn.attention(q, k, v, pos, variant="simple")
    if impl == "pallas_flash":
        return lambda q, k, v, pos: pallas_attn.attention(q, k, v, pos, variant="flash")
    raise ValueError(f"unknown attention impl: {impl!r}")


def step(
    cfg: ModelConfig,
    params: list[jax.Array],
    kv_k: jax.Array,
    kv_v: jax.Array,
    tokens: jax.Array,
    pos: jax.Array,
    last_idx: jax.Array | None = None,
    *,
    attn_impl: str = "pallas_flash",
):
    """Unified prefill-chunk / decode step.

    Args:
      params: flat list per ``param_specs`` order.
      kv_k, kv_v: [L, B, Hkv, S, D] caches (RoPE'd keys).
      tokens: [B, C] int32 new token ids.
      pos:    [B] int32 cache length before this chunk.
      last_idx: [B] int32 index of the last *real* token within the chunk
        (defaults to C-1). Lets the Rust runtime pad a chunk up to a bucket
        size while reading logits at the true position.

    Returns: (logits [B, vocab] at last_idx, new kv_k, new kv_v).
    """
    attn_fn = _attention_dispatch(attn_impl)
    specs = param_specs(cfg)
    byname = {name: p for (name, _), p in zip(specs, params)}

    b, c = tokens.shape
    positions = pos[:, None] + jnp.arange(c)[None, :]  # [B, C] global positions

    h = jnp.take(byname["embed"], tokens, axis=0)  # [B, C, d]
    new_ks, new_vs = [], []
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        x = ref.ref_rmsnorm(h, byname[p + "attn_norm"])
        q = (x @ byname[p + "wq"]).reshape(b, c, cfg.n_q_heads, cfg.head_dim)
        k = (x @ byname[p + "wk"]).reshape(b, c, cfg.n_kv_heads, cfg.head_dim)
        v = (x @ byname[p + "wv"]).reshape(b, c, cfg.n_kv_heads, cfg.head_dim)
        q = ref.ref_rope(q, positions, cfg.rope_theta)
        k = ref.ref_rope(k, positions, cfg.rope_theta)
        # [B, H, C, D] layouts for the kernel; keys cached post-RoPE.
        k_cache = _scatter_chunk(kv_k[l], k.transpose(0, 2, 1, 3), pos)
        v_cache = _scatter_chunk(kv_v[l], v.transpose(0, 2, 1, 3), pos)
        new_ks.append(k_cache)
        new_vs.append(v_cache)
        attn = attn_fn(q.transpose(0, 2, 1, 3), k_cache, v_cache, pos)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, c, cfg.q_dim)
        h = h + attn @ byname[p + "wo"]
        x = ref.ref_rmsnorm(h, byname[p + "ffn_norm"])
        gate = jax.nn.silu(x @ byname[p + "w_gate"])
        h = h + (gate * (x @ byname[p + "w_up"])) @ byname[p + "w_down"]

    if last_idx is None:
        last_idx = jnp.full((b,), c - 1, jnp.int32)
    gathered = jnp.take_along_axis(h, last_idx[:, None, None].astype(jnp.int32), axis=1)[:, 0]
    last = ref.ref_rmsnorm(gathered, byname["final_norm"])
    logits = last @ byname["lm_head"]
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def make_step_fn(cfg: ModelConfig, attn_impl: str = "pallas_flash"):
    """Closure with the (params..., kv_k, kv_v, tokens, pos, last_idx) flat
    signature that aot.py lowers. Returns a tuple so the HLO root is a
    tuple (the Rust side unwraps with to_tuple3)."""

    n_params = len(param_specs(cfg))

    def fn(*args):
        params = list(args[:n_params])
        kv_k, kv_v, tokens, pos, last_idx = args[n_params:]
        logits, nk, nv = step(
            cfg, params, kv_k, kv_v, tokens, pos, last_idx, attn_impl=attn_impl
        )
        return logits, nk, nv

    return fn


def empty_cache(cfg: ModelConfig, batch: int, capacity: int) -> tuple[jax.Array, jax.Array]:
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, capacity, cfg.head_dim)
    z = jnp.zeros(shape, jnp.dtype(cfg.dtype))
    return z, z
